//! Replica backends the router shards over: one trait, three
//! transports.
//!
//! [`InProcessReplica`] wraps a [`Server`] handle — the same coalescing
//! worker pool a single-process deployment runs, so cluster tests and
//! `lutq serve --replicas` get real batching semantics per replica.
//! [`HttpReplica`] drives a remote `lutq serve` front through
//! [`HttpClient`] with pooled keep-alive connections — the
//! process/host-sharding story (`lutq route`). [`WireReplica`] drives
//! a remote binary wire front ([`WireServer`](super::super::WireServer))
//! through pooled [`WireClient`]s: the whole shard goes out as ONE
//! batched predict frame of raw little-endian f32s, so shard hops pay
//! no JSON and no per-sample round trips (`lutq route` with `@binary`
//! replica specs).
//!
//! A replica serves a *shard* — a slice of a batch's samples — and
//! either answers every sample or fails the shard as a unit with a
//! typed [`ReplicaError`], which tells the router whether re-routing
//! can help ([`ReplicaError::Failed`]) or would fail identically
//! (deadline- and request-shaped errors).
//!
//! Pooled-connection staleness: a keep-alive connection parked in a
//! pool can be closed server-side while idle (io timeout, restart).
//! Both remote transports therefore retry exactly once on a transport
//! error over a *reused* connection — predict is pure inference, so
//! the retry is idempotent — while failures on a fresh connection
//! surface immediately (the backend really is unreachable).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::jsonic;

use super::super::batcher::ReplyError;
use super::super::http::HttpClient;
use super::super::registry::{ModelInfo, DEFAULT_VERSION};
use super::super::server::{Server, SubmitError};
use super::super::wire::frame::predict_frame_bytes;
use super::super::wire::{WireClient, WireReply};

/// Why a replica could not serve a shard.
#[derive(Debug, Clone)]
pub enum ReplicaError {
    /// Transport or execution failure (connection refused, replica
    /// shutting down, exec error): the shard is failover-eligible and
    /// the replica is marked unhealthy.
    Failed(String),
    /// The replica's admission gate turned the shard away (429). That
    /// verdict is about *this* replica's queue — the router retries the
    /// shard on survivors and only surfaces the 429 if every live
    /// replica refuses.
    Rejected(String),
    /// A shard sample overstayed its client deadline on the replica
    /// (in-queue shed): the budget is genuinely spent, so this is
    /// final — never re-routed.
    Deadline(String),
    /// The replica says the request itself is wrong (unknown model,
    /// bad input length): re-routing would fail identically.
    BadRequest(String),
}

impl std::fmt::Display for ReplicaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplicaError::Failed(m) => write!(f, "replica failed: {m}"),
            ReplicaError::Rejected(m) => {
                write!(f, "replica rejected: {m}")
            }
            ReplicaError::Deadline(m) => {
                write!(f, "deadline_exceeded: {m}")
            }
            ReplicaError::BadRequest(m) => {
                write!(f, "bad request: {m}")
            }
        }
    }
}

impl std::error::Error for ReplicaError {}

/// One backend the router can hand a shard to. Implementations must be
/// safe to call from several router dispatch threads at once.
pub trait Replica: Send + Sync {
    /// Stable display name (reports, logs).
    fn name(&self) -> &str;

    /// Serve one shard: per-sample outputs in shard order, or one error
    /// for the whole shard. Implementations must answer exactly
    /// `samples.len()` rows on success.
    fn predict_shard(
        &self,
        model: &str,
        samples: &[&[f32]],
        deadline: Option<Instant>,
    ) -> Result<Vec<Vec<f32>>, ReplicaError>;

    /// Liveness probe (in-process: still accepting; HTTP: `/healthz`
    /// answers 200). The router calls this to restore replicas it
    /// marked unhealthy after a failure.
    fn check_health(&self) -> bool;

    /// The models this replica can serve (the router's catalog source).
    fn model_infos(&self) -> Result<Vec<ModelInfo>>;

    /// Optional smoothed service-time hint in ms from the replica's own
    /// admission stats — seeds the router's shard weighting before the
    /// router has observations of its own. `None` = no data yet.
    fn ewma_hint_ms(&self) -> Option<f64> {
        None
    }

    /// Optional per-sample service-time estimate in ms read from the
    /// replica's *published* `/metrics` rows. Unlike
    /// [`Replica::ewma_hint_ms`] (a cheap inline hint), this may cost a
    /// network round trip, so the router only calls it at probe cadence
    /// (and only with `metrics_weights` enabled). `None` = transport
    /// has no metrics endpoint, the fetch failed, or no data yet.
    fn metrics_hint_ms(&self) -> Option<f64> {
        None
    }
}

/// Decorator-friendly forwarding so tests can keep a handle to a
/// wrapped replica (e.g. `testkit::flaky::FlakyReplica`) while the
/// router owns a `Box<dyn Replica>` pointing at the same object.
impl<R: Replica + ?Sized> Replica for Arc<R> {
    fn name(&self) -> &str {
        (**self).name()
    }

    fn predict_shard(
        &self,
        model: &str,
        samples: &[&[f32]],
        deadline: Option<Instant>,
    ) -> Result<Vec<Vec<f32>>, ReplicaError> {
        (**self).predict_shard(model, samples, deadline)
    }

    fn check_health(&self) -> bool {
        (**self).check_health()
    }

    fn model_infos(&self) -> Result<Vec<ModelInfo>> {
        (**self).model_infos()
    }

    fn ewma_hint_ms(&self) -> Option<f64> {
        (**self).ewma_hint_ms()
    }

    fn metrics_hint_ms(&self) -> Option<f64> {
        (**self).metrics_hint_ms()
    }
}

/// A replica living in this process: a [`Server`] worker pool behind an
/// `Arc`. Shard samples go through the server's admission gate and
/// coalescing batcher exactly like any other caller, so per-replica
/// responses keep the serve contract (bit-identical to a single-sample
/// `run_into`).
pub struct InProcessReplica {
    name: String,
    server: Arc<Server>,
}

impl InProcessReplica {
    pub fn new(name: &str, server: Arc<Server>) -> InProcessReplica {
        InProcessReplica { name: name.to_string(), server }
    }

    /// The wrapped server (tests kill it mid-load via
    /// [`Server::close`]).
    pub fn server(&self) -> &Arc<Server> {
        &self.server
    }
}

impl Replica for InProcessReplica {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_shard(
        &self,
        model: &str,
        samples: &[&[f32]],
        deadline: Option<Instant>,
    ) -> Result<Vec<Vec<f32>>, ReplicaError> {
        // submit the whole shard before waiting, so the server can
        // coalesce it; a failed submit drops the earlier tickets, which
        // the batcher reclaims as abandoned — on a closed/rejecting
        // server their answers would be discarded anyway
        let mut tickets = Vec::with_capacity(samples.len());
        for s in samples {
            match self.server.try_submit(model, s, deadline) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::UnknownModel(m))
                | Err(SubmitError::BadInput(m)) => {
                    return Err(ReplicaError::BadRequest(m))
                }
                Err(SubmitError::Rejected(r)) => {
                    return Err(ReplicaError::Rejected(r.to_string()))
                }
                Err(SubmitError::QueueDeadline(m)) => {
                    return Err(ReplicaError::Deadline(m))
                }
                Err(SubmitError::Closed(m)) => {
                    return Err(ReplicaError::Failed(m))
                }
            }
        }
        // wait EVERY ticket even after one fails: dropping the rest
        // un-waited would abandon queued work (wasted compute and
        // nonzero `abandoned` counters); the first error still decides
        // the shard's fate
        let mut out = Vec::with_capacity(tickets.len());
        let mut first_err: Option<ReplicaError> = None;
        for t in tickets {
            match t.wait_reply(None) {
                Ok(row) => out.push(row),
                Err(e) if first_err.is_none() => {
                    first_err = Some(match e {
                        ReplyError::DeadlineExceeded(m) => {
                            ReplicaError::Deadline(m)
                        }
                        ReplyError::Failed(m) => {
                            ReplicaError::Failed(m)
                        }
                    });
                }
                Err(_) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn check_health(&self) -> bool {
        self.server.is_open()
    }

    fn model_infos(&self) -> Result<Vec<ModelInfo>> {
        Ok(self.server.registry().infos())
    }

    /// Per-sample hint from the server's own stats: the admission gate
    /// smooths per-*batch* service time, so divide by the observed mean
    /// batch size to match the router's per-sample weighting units.
    fn ewma_hint_ms(&self) -> Option<f64> {
        self.server
            .reports()
            .iter()
            .filter(|r| r.ewma_batch_ms > 0.0)
            .map(|r| r.ewma_batch_ms / r.mean_batch.max(1.0))
            .fold(None, |acc: Option<f64>, ms| {
                Some(acc.map_or(ms, |a| a.max(ms)))
            })
    }

    /// In-process, the "published metrics" ARE the admission stats the
    /// inline hint reads — same number, no round trip.
    fn metrics_hint_ms(&self) -> Option<f64> {
        self.ewma_hint_ms()
    }
}

/// How many idle keep-alive connections an [`HttpReplica`] or
/// [`WireReplica`] keeps around. Past this, finished connections are
/// dropped (closed).
const CONN_POOL: usize = 8;

/// Forward what is left of the client deadline, read at dispatch time
/// so routing overhead shrinks it. `Err` = the budget is already spent.
fn remaining_deadline_ms(
    deadline: Option<Instant>,
) -> Result<Option<f64>, ReplicaError> {
    match deadline {
        None => Ok(None),
        Some(d) => {
            let left = d.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(ReplicaError::Deadline(
                    "client deadline spent before dispatch".to_string(),
                ));
            }
            Ok(Some(left.as_secs_f64() * 1e3))
        }
    }
}

/// Parse a `/v1/models`-shaped listing body into [`ModelInfo`] rows —
/// shared by the HTTP and wire replicas (both transports publish the
/// identical catalog JSON).
fn parse_model_listing(addr: &str,
                       body: &str) -> Result<Vec<ModelInfo>> {
    let j = jsonic::parse(body).map_err(|e| {
        anyhow!("cluster: {addr}: malformed model listing: {e}")
    })?;
    let rows = j.get("models").and_then(|m| m.as_arr()).ok_or_else(
        || anyhow!("cluster: {addr}: listing lacks `models`"),
    )?;
    rows.iter()
        .map(|r| {
            Ok(ModelInfo {
                name: r
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| {
                        anyhow!("cluster: model row lacks `name`")
                    })?
                    .to_string(),
                // pre-versioning replicas omit these fields; treat
                // their single catalog row as the default v1
                version: r
                    .get("version")
                    .and_then(|v| v.as_str())
                    .unwrap_or(DEFAULT_VERSION)
                    .to_string(),
                default: r
                    .get("default")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(true),
                backend: r
                    .get("backend")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                input: r
                    .get("input")
                    .and_then(|v| v.as_shape())
                    .ok_or_else(|| {
                        anyhow!("cluster: model row lacks `input`")
                    })?,
                output: r
                    .get("output")
                    .and_then(|v| v.as_shape())
                    .unwrap_or_default(),
                batch_invariant: r
                    .get("batch_invariant")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
            })
        })
        .collect()
}

/// Pull a conservative per-sample service-time estimate out of a
/// `/metrics` body (a JSON array of event rows): the worst per-model
/// `ewma_batch_ms / mean_batch` across the replica's `serve_model`
/// rows — the same figure [`InProcessReplica::ewma_hint_ms`] computes
/// from its in-process handle. Shared by both remote transports (they
/// publish identical metrics JSON).
fn hint_from_metric_rows(body: &str) -> Option<f64> {
    let rows = jsonic::parse(body).ok()?;
    let rows = rows.as_arr()?;
    rows.iter()
        .filter(|r| {
            r.get("event").and_then(|e| e.as_str())
                == Some("serve_model")
        })
        .filter_map(|r| {
            let ewma =
                r.get("ewma_batch_ms").and_then(|v| v.as_f64())?;
            if ewma <= 0.0 {
                return None;
            }
            let mean_batch = r
                .get("mean_batch")
                .and_then(|v| v.as_f64())
                .unwrap_or(1.0);
            Some(ewma / mean_batch.max(1.0))
        })
        .fold(None, |acc: Option<f64>, ms| {
            Some(acc.map_or(ms, |a| a.max(ms)))
        })
}

/// A replica behind a remote `lutq serve` (or `lutq route`) front,
/// driven over keep-alive HTTP/1.1. Connections are pooled per
/// replica; a shard's samples are dispatched concurrently (one pooled
/// connection each) so the remote front can coalesce them into a
/// batch — sequential round trips would serialize the shard's latency
/// and force batch-1 execution remotely. A connection is returned to
/// the pool after any cleanly-framed exchange (200/4xx/429 alike) and
/// discarded only on transport errors.
pub struct HttpReplica {
    name: String,
    addr: String,
    conns: Mutex<Vec<HttpClient>>,
}

impl HttpReplica {
    /// `addr` is `host:port` of the replica's HTTP front.
    pub fn new(addr: &str) -> HttpReplica {
        HttpReplica {
            name: format!("http://{addr}"),
            addr: addr.to_string(),
            conns: Mutex::new(Vec::new()),
        }
    }

    /// Lease a connection; `true` = reused from the pool, which may
    /// have gone stale while idle.
    fn lease(&self) -> Result<(HttpClient, bool), ReplicaError> {
        if let Some(c) = self.conns.lock().unwrap().pop() {
            return Ok((c, true));
        }
        HttpClient::connect(&self.addr).map(|c| (c, false)).map_err(
            |e| {
                ReplicaError::Failed(format!(
                    "connect {}: {e:#}",
                    self.addr
                ))
            },
        )
    }

    fn release(&self, client: HttpClient) {
        let mut pool = self.conns.lock().unwrap();
        if pool.len() < CONN_POOL {
            pool.push(client);
        }
    }

    /// One sample's full round trip on a pooled connection. A
    /// transport error over a *reused* connection retries exactly once
    /// on a fresh one (see the module doc); fresh-connection failures
    /// surface immediately.
    fn predict_once(
        &self,
        model: &str,
        sample: &[f32],
        deadline: Option<Instant>,
    ) -> Result<Vec<f32>, ReplicaError> {
        let deadline_ms = remaining_deadline_ms(deadline)?;
        let body =
            format!("{{\"input\":{}}}", jsonic::Json::from_f32s(sample));
        let (mut client, reused) = self.lease()?;
        let (status, reply) = match client
            .predict(model, &body, deadline_ms)
        {
            // the exchange framed cleanly whatever the status; keep
            // the connection — recycling it on 429s would make
            // overload (when 429s are common) pay a fresh connect per
            // shard
            Ok(r) => {
                self.release(client);
                r
            }
            Err(_) if reused => {
                // stale pooled connection (closed server-side while
                // idle): drop it and retry exactly once, fresh
                drop(client);
                let mut fresh = HttpClient::connect(&self.addr)
                    .map_err(|e| {
                        ReplicaError::Failed(format!(
                            "connect {}: {e:#}",
                            self.addr
                        ))
                    })?;
                let r = fresh
                    .predict(model, &body, deadline_ms)
                    .map_err(|e| {
                        ReplicaError::Failed(format!(
                            "predict on {}: {e:#}",
                            self.addr
                        ))
                    })?;
                self.release(fresh);
                r
            }
            Err(e) => {
                return Err(ReplicaError::Failed(format!(
                    "predict on {}: {e:#}",
                    self.addr
                )))
            }
        };
        match status {
            200 => jsonic::parse(&reply)
                .ok()
                .and_then(|j| {
                    j.get("output").and_then(|o| o.as_f32_vec())
                })
                .ok_or_else(|| {
                    ReplicaError::Failed(format!(
                        "{}: malformed 200 predict body",
                        self.addr
                    ))
                }),
            429 => Err(ReplicaError::Rejected(reply)),
            400 | 404 => Err(ReplicaError::BadRequest(reply)),
            code => Err(ReplicaError::Failed(format!(
                "{}: predict answered {code}: {reply}",
                self.addr
            ))),
        }
    }
}

impl Replica for HttpReplica {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_shard(
        &self,
        model: &str,
        samples: &[&[f32]],
        deadline: Option<Instant>,
    ) -> Result<Vec<Vec<f32>>, ReplicaError> {
        if samples.len() == 1 {
            return Ok(vec![self.predict_once(
                model, samples[0], deadline,
            )?]);
        }
        // concurrent round trips, one pooled connection each: the
        // remote coalescing batcher sees the whole shard at once and
        // shard latency stays ~one request, not samples.len() of them
        let mut slots: Vec<
            Option<Result<Vec<f32>, ReplicaError>>,
        > = (0..samples.len()).map(|_| None).collect();
        std::thread::scope(|sc| {
            for (s, slot) in samples.iter().zip(slots.iter_mut()) {
                sc.spawn(move || {
                    *slot = Some(self.predict_once(model, s, deadline));
                });
            }
        });
        let mut out = Vec::with_capacity(samples.len());
        let mut first_err: Option<ReplicaError> = None;
        for r in slots {
            match r.expect("every request ran") {
                Ok(row) => out.push(row),
                Err(e) if first_err.is_none() => first_err = Some(e),
                Err(_) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn check_health(&self) -> bool {
        HttpClient::connect(&self.addr)
            .and_then(|mut c| c.get("/healthz"))
            .map(|(status, _)| status == 200)
            .unwrap_or(false)
    }

    fn model_infos(&self) -> Result<Vec<ModelInfo>> {
        let mut client = HttpClient::connect(&self.addr)
            .with_context(|| format!("cluster: connect {}", self.addr))?;
        let (status, body) = client
            .get("/v1/models")
            .with_context(|| format!("cluster: list {}", self.addr))?;
        ensure!(status == 200,
                "cluster: {} answered {status} to /v1/models: {body}",
                self.addr);
        parse_model_listing(&self.addr, &body)
    }

    fn metrics_hint_ms(&self) -> Option<f64> {
        let (status, body) = HttpClient::connect(&self.addr)
            .and_then(|mut c| c.get("/metrics"))
            .ok()?;
        if status != 200 {
            return None;
        }
        hint_from_metric_rows(&body)
    }
}

/// A replica behind a remote binary wire front
/// ([`WireServer`](super::super::WireServer)), driven over pooled
/// keep-alive [`WireClient`]s — `lutq route` with `@binary` replica
/// specs.
///
/// Unlike [`HttpReplica`], which needs one connection per sample so
/// the remote batcher can coalesce, a wire shard is ONE batched
/// predict frame on one pooled connection: the
/// [`WireServer`](super::super::WireServer) fans the frame's samples
/// out to its backend concurrently on arrival. The shard hop pays a
/// single round trip of raw little-endian f32 bytes — no JSON, no
/// per-sample connections.
pub struct WireReplica {
    name: String,
    addr: String,
    conns: Mutex<Vec<WireClient>>,
}

impl WireReplica {
    /// `addr` is `host:port` of the replica's wire front.
    pub fn new(addr: &str) -> WireReplica {
        WireReplica {
            name: format!("wire://{addr}"),
            addr: addr.to_string(),
            conns: Mutex::new(Vec::new()),
        }
    }

    /// Lease a connection; `true` = reused from the pool, which may
    /// have gone stale while idle.
    fn lease(&self) -> Result<(WireClient, bool), ReplicaError> {
        if let Some(c) = self.conns.lock().unwrap().pop() {
            return Ok((c, true));
        }
        WireClient::connect(&self.addr).map(|c| (c, false)).map_err(
            |e| {
                ReplicaError::Failed(format!(
                    "connect {}: {e:#}",
                    self.addr
                ))
            },
        )
    }

    fn release(&self, client: WireClient) {
        let mut pool = self.conns.lock().unwrap();
        if pool.len() < CONN_POOL {
            pool.push(client);
        }
    }

    /// One pre-encoded predict frame's round trip on a pooled
    /// connection, with the same retry-exactly-once-on-stale-reuse
    /// policy as [`HttpReplica::predict_once`].
    fn exchange(&self,
                frame: &[u8]) -> Result<WireReply, ReplicaError> {
        let (mut client, reused) = self.lease()?;
        match client.request_frame(frame) {
            // any well-formed reply (outputs or a typed refusal) means
            // the connection is still in sync; keep it pooled
            Ok(r) => {
                self.release(client);
                Ok(r)
            }
            Err(_) if reused => {
                // stale pooled connection (closed server-side while
                // idle): drop it and retry exactly once, fresh
                drop(client);
                let mut fresh = WireClient::connect(&self.addr)
                    .map_err(|e| {
                        ReplicaError::Failed(format!(
                            "connect {}: {e:#}",
                            self.addr
                        ))
                    })?;
                let r = fresh.request_frame(frame).map_err(|e| {
                    ReplicaError::Failed(format!(
                        "predict on {}: {e:#}",
                        self.addr
                    ))
                })?;
                self.release(fresh);
                Ok(r)
            }
            Err(e) => Err(ReplicaError::Failed(format!(
                "predict on {}: {e:#}",
                self.addr
            ))),
        }
    }
}

impl Replica for WireReplica {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_shard(
        &self,
        model: &str,
        samples: &[&[f32]],
        deadline: Option<Instant>,
    ) -> Result<Vec<Vec<f32>>, ReplicaError> {
        let deadline_ms = remaining_deadline_ms(deadline)?;
        let frame = predict_frame_bytes(model, samples, deadline_ms)
            .map_err(|e| {
                ReplicaError::BadRequest(format!(
                    "encode shard for {}: {e}",
                    self.addr
                ))
            })?;
        match self.exchange(&frame)? {
            WireReply::Outputs(rows) => {
                if rows.len() != samples.len() {
                    return Err(ReplicaError::Failed(format!(
                        "{}: answered {} rows for {} samples",
                        self.addr,
                        rows.len(),
                        samples.len()
                    )));
                }
                Ok(rows)
            }
            WireReply::Refused(e) => Err(match e.status {
                429 => ReplicaError::Rejected(e.message),
                400 | 404 => ReplicaError::BadRequest(e.message),
                _ => ReplicaError::Failed(format!(
                    "{}: predict answered {} ({}): {}",
                    self.addr, e.status, e.code, e.message
                )),
            }),
        }
    }

    fn check_health(&self) -> bool {
        WireClient::connect(&self.addr)
            .and_then(|mut c| c.healthz())
            .map(|(status, _)| status == 200)
            .unwrap_or(false)
    }

    fn model_infos(&self) -> Result<Vec<ModelInfo>> {
        let mut client = WireClient::connect(&self.addr)
            .with_context(|| format!("cluster: connect {}", self.addr))?;
        let (status, body) = client
            .models()
            .with_context(|| format!("cluster: list {}", self.addr))?;
        ensure!(status == 200,
                "cluster: {} answered {status} to models: {body}",
                self.addr);
        parse_model_listing(&self.addr, &body)
    }

    fn metrics_hint_ms(&self) -> Option<f64> {
        let (status, body) = WireClient::connect(&self.addr)
            .and_then(|mut c| c.metrics())
            .ok()?;
        if status != 200 {
            return None;
        }
        hint_from_metric_rows(&body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{ExecMode, KernelBackend, Plan, PlanOptions};
    use crate::serve::{Registry, Server, ServerConfig};
    use crate::testkit::models::synth_mlp_model;
    use std::time::Duration;

    fn server() -> Arc<Server> {
        let (graph, model) = synth_mlp_model(4);
        let plan = Plan::compile(
            &graph,
            &model,
            PlanOptions {
                mode: ExecMode::LutTrick,
                act_bits: 0,
                mlbn: false,
                threads: 1,
                kernel: KernelBackend::Scalar,
            },
            &[16],
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.register("mlp", plan).unwrap();
        Arc::new(
            Server::start(
                reg,
                ServerConfig {
                    workers: 1,
                    max_batch: 4,
                    linger: Duration::from_millis(1),
                    queue_cap: 32,
                    ..Default::default()
                },
            )
            .unwrap(),
        )
    }

    #[test]
    fn in_process_replica_serves_shards_and_reports_models() {
        let rep = InProcessReplica::new("r0", server());
        assert!(rep.check_health());
        let infos = rep.model_infos().unwrap();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].name, "mlp");
        let a = vec![0.25f32; 16];
        let b = vec![-0.5f32; 16];
        let rows = rep
            .predict_shard("mlp", &[a.as_slice(), b.as_slice()], None)
            .unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), 10);
        // admission stats have flowed into the weighting hint
        assert!(rep.ewma_hint_ms().unwrap() > 0.0);
    }

    #[test]
    fn in_process_replica_maps_submit_failures() {
        let rep = InProcessReplica::new("r0", server());
        let a = vec![0.0f32; 16];
        let short = vec![0.0f32; 3];
        assert!(matches!(
            rep.predict_shard("nope", &[a.as_slice()], None),
            Err(ReplicaError::BadRequest(_))
        ));
        assert!(matches!(
            rep.predict_shard("mlp", &[short.as_slice()], None),
            Err(ReplicaError::BadRequest(_))
        ));
        // a spent deadline is rejected by admission, not failed over
        assert!(matches!(
            rep.predict_shard("mlp", &[a.as_slice()], Some(Instant::now())),
            Err(ReplicaError::Rejected(_))
        ));
        // a closed server is a transport-style failure: failover bait
        rep.server().close();
        assert!(!rep.check_health());
        assert!(matches!(
            rep.predict_shard("mlp", &[a.as_slice()], None),
            Err(ReplicaError::Failed(_))
        ));
    }

    #[test]
    fn metrics_hint_takes_worst_model_per_sample_time() {
        let body = r#"[
            {"event":"serve_cluster","submitted":10},
            {"event":"serve_model","model":"a","ewma_batch_ms":8.0,
             "mean_batch":4.0},
            {"event":"serve_model","model":"b","ewma_batch_ms":3.0,
             "mean_batch":1.0},
            {"event":"serve_model","model":"cold","ewma_batch_ms":0.0,
             "mean_batch":0.0}
        ]"#;
        // a: 8/4 = 2 ms; b: 3/1 = 3 ms; cold rows are skipped
        assert_eq!(hint_from_metric_rows(body), Some(3.0));
        assert_eq!(hint_from_metric_rows("[]"), None);
        assert_eq!(hint_from_metric_rows("not json"), None);
        // in-process replicas publish the same figure both ways
        let rep = InProcessReplica::new("r0", server());
        let a = vec![0.25f32; 16];
        rep.predict_shard("mlp", &[a.as_slice()], None).unwrap();
        assert_eq!(rep.metrics_hint_ms(), rep.ewma_hint_ms());
    }

    #[test]
    fn http_replica_reports_dead_backends_unhealthy() {
        // nothing listens here; connect must fail cleanly
        let rep = HttpReplica::new("127.0.0.1:1");
        assert!(!rep.check_health());
        let a = vec![0.0f32; 16];
        assert!(matches!(
            rep.predict_shard("mlp", &[a.as_slice()], None),
            Err(ReplicaError::Failed(_))
        ));
        assert!(rep.model_infos().is_err());
    }

    #[test]
    fn wire_replica_reports_dead_backends_unhealthy() {
        // nothing listens here; a fresh-connect failure must NOT be
        // retried — it surfaces as a failed shard straight away
        let rep = WireReplica::new("127.0.0.1:1");
        assert!(!rep.check_health());
        let a = vec![0.0f32; 16];
        assert!(matches!(
            rep.predict_shard("mlp", &[a.as_slice()], None),
            Err(ReplicaError::Failed(_))
        ));
        assert!(rep.model_infos().is_err());
    }
}
