//! Per-replica circuit breaker with exponential backoff.
//!
//! PR 5's recovery story was a single probe: one failed shard marked a
//! replica unhealthy, and the next `check_health` sweep restored it —
//! so a crash-looping backend was re-probed (and re-trusted) at the
//! full probe cadence forever. The breaker replaces that flag with the
//! classic three-state machine:
//!
//! * **Closed** — traffic flows. A failure trips the breaker open.
//! * **Open** — the replica is shunned for a backoff window. No shards,
//!   no probes; the window is the only cost a dead backend imposes.
//! * **Half-open** — the backoff expired; trial traffic (a probe or a
//!   live shard) is admitted. Success closes the breaker and resets the
//!   backoff to its base; failure re-opens it with the backoff
//!   *doubled*, up to a cap — so a backend that keeps dying is probed
//!   exponentially less often.
//!
//! Concurrency: the state sits behind one small mutex, touched once per
//! shard outcome / probe — nowhere near the dispatch hot path's scale.
//! Several in-flight shards may fail together while the breaker is
//! already open; those late failures are absorbed without doubling the
//! backoff again (only a failed *half-open trial* escalates).

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Backoff bounds for a [`CircuitBreaker`].
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// first backoff window after a trip (ms)
    pub base_ms: f64,
    /// backoff growth cap (ms)
    pub max_ms: f64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { base_ms: 200.0, max_ms: 5_000.0 }
    }
}

impl BreakerConfig {
    fn base(&self) -> Duration {
        Duration::from_secs_f64((self.base_ms.max(0.1)) / 1e3)
    }

    fn cap(&self) -> Duration {
        Duration::from_secs_f64(
            (self.max_ms.max(self.base_ms).max(0.1)) / 1e3,
        )
    }
}

/// Observable breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    /// tripped, backoff window still running
    Open,
    /// backoff expired; trial traffic admitted
    HalfOpen,
}

impl BreakerState {
    /// Stable lowercase name for reports/JSONL.
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

struct Inner {
    closed: bool,
    /// backoff applied at the most recent (re)open
    backoff: Duration,
    /// when the current backoff window expires (meaningful while open)
    until: Instant,
    trips: u64,
}

/// The three-state breaker. Thread-safe; all methods take `&self`.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    inner: Mutex<Inner>,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            inner: Mutex::new(Inner {
                closed: true,
                backoff: cfg.base(),
                until: Instant::now(),
                trips: 0,
            }),
        }
    }

    pub fn state(&self) -> BreakerState {
        let g = self.inner.lock().unwrap();
        if g.closed {
            BreakerState::Closed
        } else if Instant::now() >= g.until {
            BreakerState::HalfOpen
        } else {
            BreakerState::Open
        }
    }

    /// Closed — the healthy steady state.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// May traffic flow right now? Closed always; half-open admits
    /// trial traffic (whose outcome decides the next state); open
    /// (backoff pending) admits nothing.
    pub fn admits(&self) -> bool {
        self.state() != BreakerState::Open
    }

    /// Closed → open transitions so far.
    pub fn trips(&self) -> u64 {
        self.inner.lock().unwrap().trips
    }

    /// A success (served shard or answered probe): closes the breaker
    /// and resets the backoff to its base.
    pub fn record_success(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        g.backoff = self.cfg.base();
    }

    /// A failure (failed shard or probe). Closed: trip open with the
    /// base backoff. Half-open (trial failed): re-open with the backoff
    /// doubled, capped. Open with the window still running: absorbed —
    /// concurrent in-flight failures from one outage must not compound
    /// the backoff.
    pub fn record_failure(&self) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        if g.closed {
            g.closed = false;
            g.backoff = self.cfg.base();
            g.until = now + g.backoff;
            g.trips += 1;
        } else if now >= g.until {
            let doubled = g.backoff.saturating_mul(2);
            g.backoff = doubled.min(self.cfg.cap());
            g.until = now + g.backoff;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> CircuitBreaker {
        CircuitBreaker::new(BreakerConfig { base_ms: 20.0, max_ms: 100.0 })
    }

    #[test]
    fn starts_closed_and_admitting() {
        let b = fast();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admits());
        assert!(b.is_closed());
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn open_backoff_half_open_closed_cycle() {
        let b = fast();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admits());
        assert_eq!(b.trips(), 1);
        // backoff expires -> half-open admits a trial
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(b.admits());
        // successful trial closes and resets
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn failed_trial_doubles_backoff_up_to_the_cap() {
        let b = fast();
        b.record_failure(); // open, 20 ms
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_failure(); // trial failed -> open, 40 ms
        assert_eq!(b.state(), BreakerState::Open);
        // 40 ms window: still open after the old 20 ms would have passed
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // keep failing: 80 -> capped at 100, never beyond
        b.record_failure();
        std::thread::sleep(Duration::from_millis(90));
        b.record_failure();
        {
            let g = b.inner.lock().unwrap();
            assert_eq!(g.backoff, Duration::from_millis(100));
        }
        // one trip only: re-opens are not new trips
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn concurrent_failures_inside_the_window_do_not_compound() {
        let b = fast();
        b.record_failure();
        b.record_failure();
        b.record_failure();
        let g = b.inner.lock().unwrap();
        assert_eq!(g.backoff, Duration::from_millis(20));
        assert_eq!(g.trips, 1);
    }

    #[test]
    fn success_resets_backoff_to_base() {
        let b = fast();
        b.record_failure();
        std::thread::sleep(Duration::from_millis(30));
        b.record_failure(); // doubled to 40
        b.record_success();
        assert!(b.is_closed());
        // next trip starts from base again
        b.record_failure();
        let g = b.inner.lock().unwrap();
        assert_eq!(g.backoff, Duration::from_millis(20));
    }
}
