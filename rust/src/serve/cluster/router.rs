//! The routing tier: split a batch across replicas, dispatch shards
//! concurrently, fail over around dead backends, merge in request
//! order, and account for every sample exactly once.
//!
//! Weighting: each replica carries an EWMA of observed per-sample
//! service time; weight is its reciprocal, so faster replicas take
//! larger shards. Before the router has its own observations it falls
//! back to the replica's [`Replica::ewma_hint_ms`] (the in-process
//! replica feeds its admission EWMA through that seam), and before any
//! data at all every replica weighs the same. With
//! [`RouterConfig::metrics_weights`] set, the estimate prefers the
//! replica's own published `/metrics` rows (refreshed by the health
//! prober via [`Replica::metrics_hint_ms`]) over router-side
//! observations — useful when several routers share one fleet and each
//! sees only a slice of the traffic. Single-sample requests — the HTTP
//! front's shape — spread by smooth weighted round-robin instead of a
//! proportional split (which would pin every 1-sample batch to the
//! momentarily-fastest replica).
//!
//! Hedging (off by default; arm with [`RouterConfig::hedge_threshold`]
//! > 1): when a dispatched shard's elapsed time exceeds
//! `hedge_threshold ×` the expected shard time (the replica's EWMA ×
//! shard size, floored at [`RouterConfig::hedge_min_ms`]), the shard is
//! re-dispatched to the fastest *idle* survivor and the first
//! completion wins; the straggler's result is discarded when it
//! eventually lands. Only the winning completion counts `samples`, so
//! per-sample accounting stays exact and
//! [`ClusterTotals::reconciles`] holds under hedging. Duplicate
//! dispatches and their outcomes are visible as
//! `hedges`/`hedge_wins`/`hedge_losses` in [`ReplicaReport`].
//!
//! Failover: a shard that fails with [`ReplicaError::Failed`] trips its
//! replica's circuit breaker (see [`super::breaker`]), excludes it for
//! the rest of the batch, and re-routes the shard's samples across the
//! survivors. An admission refusal ([`ReplicaError::Rejected`])
//! reflects *that replica's* congestion, so it too retries on survivors
//! (without tripping the breaker); the client sees the 429 only when
//! every live replica refused. A genuinely spent budget
//! ([`ReplicaError::Deadline`]: shed in a replica queue, or expired
//! while routing) is final — re-routing cannot conjure time back.
//! Tripped replicas sit out an exponentially growing backoff window and
//! rejoin through a successful half-open trial — either a periodic
//! [`Router::tick`] probe (as `lutq route` wires) or a live shard that
//! happens to land during the half-open window. [`Router::check_health`]
//! remains the force-probe-everything escape hatch (used on demand and
//! by the all-replicas-down path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::metrics::Metrics;
use crate::jsonic::Json;
use crate::util::Timer;

use super::super::http::{PredictError, ServeBackend};
use super::super::registry::{split_versioned, ModelInfo};
use super::breaker::{BreakerConfig, BreakerState, CircuitBreaker};
use super::replica::{Replica, ReplicaError};
use super::shard::{chunk, merge, split, Shard};

/// EWMA smoothing for observed per-sample service time — same horizon
/// as the admission gate's batch EWMA (~last 5 observations dominate).
const EWMA_ALPHA: f64 = 0.2;

/// One sample's routed outcome.
type SampleResult = std::result::Result<Vec<f32>, RouteError>;
/// One shard's outcome as a unit.
type ShardResult = std::result::Result<Vec<Vec<f32>>, ReplicaError>;

/// Routing knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Max samples of one batch handed to a replica as a single shard
    /// (batch-coupled models always shard at 1). Smaller shards spread
    /// wider and fail over at finer grain; larger shards amortize
    /// per-request transport cost.
    pub max_shard: usize,
    /// Hedge a shard once its elapsed time exceeds this multiple of
    /// the replica's expected shard time (EWMA × shard size). 0.0
    /// disables hedging; enabled values must be > 1.0 — a threshold at
    /// or below 1× would duplicate every shard.
    pub hedge_threshold: f64,
    /// Floor for the hedge trigger in ms, so sub-millisecond EWMAs do
    /// not turn scheduling jitter into a hedge storm.
    pub hedge_min_ms: f64,
    /// Per-replica circuit breaker backoff bounds.
    pub breaker: BreakerConfig,
    /// Prefer the replica-published `/metrics` service-time estimate
    /// (refreshed by health probing) over router-side EWMAs when
    /// weighting shards.
    pub metrics_weights: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            max_shard: 8,
            hedge_threshold: 0.0,
            hedge_min_ms: 1.0,
            breaker: BreakerConfig::default(),
            metrics_weights: false,
        }
    }
}

/// Why the router could not answer a sample.
#[derive(Debug, Clone)]
pub enum RouteError {
    /// no such model in the cluster catalog (HTTP 404)
    UnknownModel(String),
    /// sample length does not match the model's input dims (HTTP 400)
    BadInput(String),
    /// a replica's admission gate refused the deadline (HTTP 429)
    Rejected(String),
    /// the client deadline was spent while routing or queueing (429)
    Deadline(String),
    /// every replica is down or already failed this batch (HTTP 503)
    AllReplicasDown(String),
    /// execution/transport failure that exhausted failover (HTTP 500)
    Failed(String),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m)
            | RouteError::BadInput(m)
            | RouteError::Rejected(m)
            | RouteError::Failed(m) => write!(f, "{m}"),
            RouteError::Deadline(m) => {
                write!(f, "deadline_exceeded: {m}")
            }
            RouteError::AllReplicasDown(m) => {
                write!(f, "no healthy replicas: {m}")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Per-replica routing state: breaker, speed estimates, counters.
/// Behind an `Arc` so detached hedge attempts outlive the batch that
/// spawned them and still account their outcome.
struct ReplicaState {
    breaker: CircuitBreaker,
    /// EWMA of per-sample service time in ms, stored as f64 bits
    /// (0.0 = no observation yet)
    ewma_sample_ms: AtomicU64,
    /// replica-published per-sample estimate from its `/metrics` rows,
    /// f64 bits (0.0 = none fetched yet); refreshed by health probing
    remote_ewma_ms: AtomicU64,
    /// shards currently in flight here (hedging targets idle replicas)
    inflight: AtomicU64,
    /// shards dispatched to this replica (hedge duplicates included)
    shards: AtomicU64,
    /// samples this replica answered successfully (winning completions
    /// only — a discarded hedge loser counts nothing)
    samples: AtomicU64,
    /// shards that came back `ReplicaError::Failed`
    failed_shards: AtomicU64,
    /// samples re-routed to survivors after this replica failed them
    rerouted: AtomicU64,
    /// hedge duplicates dispatched *to* this replica
    hedges: AtomicU64,
    /// hedge duplicates whose completion won the race
    hedge_wins: AtomicU64,
    /// hedge duplicates that lost (primary answered first)
    hedge_losses: AtomicU64,
}

impl ReplicaState {
    fn new(breaker: BreakerConfig) -> ReplicaState {
        ReplicaState {
            breaker: CircuitBreaker::new(breaker),
            ewma_sample_ms: AtomicU64::new(0f64.to_bits()),
            remote_ewma_ms: AtomicU64::new(0f64.to_bits()),
            inflight: AtomicU64::new(0),
            shards: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            failed_shards: AtomicU64::new(0),
            rerouted: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            hedge_losses: AtomicU64::new(0),
        }
    }

    fn ewma_ms(&self) -> f64 {
        f64::from_bits(self.ewma_sample_ms.load(Ordering::Relaxed))
    }

    fn remote_ms(&self) -> f64 {
        f64::from_bits(self.remote_ewma_ms.load(Ordering::Relaxed))
    }

    /// Fold one observed per-sample service time into the EWMA (racy
    /// read-modify-write by design; it smooths a noisy signal).
    fn observe(&self, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        let prev = self.ewma_ms();
        let next = if prev == 0.0 {
            ms
        } else {
            prev + EWMA_ALPHA * (ms - prev)
        };
        self.ewma_sample_ms.store(next.to_bits(), Ordering::Relaxed);
    }
}

/// Router-level sample accounting. Every submitted sample ends in
/// exactly one of the four outcome buckets.
struct TotalCounters {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    failed: AtomicU64,
}

/// Snapshot of the router's sample accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterTotals {
    /// samples entering `predict_batch`/`predict_one`
    pub submitted: u64,
    /// answered with logits
    pub completed: u64,
    /// refused by a replica's admission gate (429)
    pub rejected: u64,
    /// client deadline spent while routing or queued (429)
    pub shed: u64,
    /// bad requests, exhausted failover, or no healthy replica
    pub failed: u64,
}

impl ClusterTotals {
    /// The accounting invariant the fault-injection tests pin:
    /// `rejected + shed + completed + failed == submitted`.
    pub fn reconciles(&self) -> bool {
        self.rejected + self.shed + self.completed + self.failed
            == self.submitted
    }

    /// One `coordinator::metrics`-style JSONL event.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::str("serve_cluster")),
            ("schema_version",
             Json::num(crate::report::SCHEMA_VERSION as f64)),
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("failed", Json::num(self.failed as f64)),
        ])
    }
}

/// One replica's routing summary — the per-replica rows next to the
/// per-model `serve_model` rows in the metrics JSONL.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub replica: String,
    /// breaker closed (the healthy steady state)
    pub healthy: bool,
    /// breaker state name: `closed`, `open`, or `half-open`
    pub breaker_state: &'static str,
    /// closed → open breaker transitions
    pub breaker_trips: u64,
    /// shards dispatched here (hedge duplicates included)
    pub shards: u64,
    /// samples answered here (winning completions only)
    pub samples: u64,
    /// shards that failed here (each tripped/held open the breaker)
    pub failed_shards: u64,
    /// samples re-routed to survivors after failing here
    pub rerouted: u64,
    /// hedge duplicates dispatched to this replica
    pub hedges: u64,
    /// hedge duplicates that won the completion race
    pub hedge_wins: u64,
    /// hedge duplicates that lost (the primary answered first)
    pub hedge_losses: u64,
    /// smoothed per-sample service time the shard weighting uses
    pub ewma_sample_ms: f64,
    /// samples answered here / router uptime
    pub images_per_sec: f64,
}

impl ReplicaReport {
    /// One `coordinator::metrics`-style JSONL event.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::str("serve_replica")),
            ("schema_version",
             Json::num(crate::report::SCHEMA_VERSION as f64)),
            ("replica", Json::str(&self.replica)),
            ("healthy", Json::Bool(self.healthy)),
            ("breaker_state", Json::str(self.breaker_state)),
            ("breaker_trips", Json::num(self.breaker_trips as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("samples", Json::num(self.samples as f64)),
            ("failed_shards", Json::num(self.failed_shards as f64)),
            ("rerouted", Json::num(self.rerouted as f64)),
            ("hedges", Json::num(self.hedges as f64)),
            ("hedge_wins", Json::num(self.hedge_wins as f64)),
            ("hedge_losses", Json::num(self.hedge_losses as f64)),
            ("ewma_sample_ms", Json::num(self.ewma_sample_ms)),
            ("images_per_sec", Json::num(self.images_per_sec)),
        ])
    }
}

/// Smooth weighted round-robin step over positive weights
/// (nginx-style): every eligible replica gains its weight in credit,
/// the richest serves and pays the round's total back. Pure so the
/// single-sample spreading property is unit-testable without standing
/// up a cluster.
pub(crate) fn smooth_wrr(credits: &mut [f64], weights: &[f64]) -> usize {
    let total: f64 = weights.iter().filter(|&&w| w > 0.0).sum();
    let mut best = 0usize;
    let mut best_credit = f64::NEG_INFINITY;
    for (i, &w) in weights.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        credits[i] += w;
        if credits[i] > best_credit {
            best = i;
            best_credit = credits[i];
        }
    }
    credits[best] -= total;
    best
}

/// The scale-out front: shards batches over [`Replica`] backends.
/// `Send + Sync`; share behind an `Arc` (the HTTP front does).
pub struct Router {
    replicas: Vec<Arc<dyn Replica>>,
    states: Vec<Arc<ReplicaState>>,
    totals: TotalCounters,
    /// model catalog (identical across replicas by deployment
    /// contract); behind a lock because [`Router::tick`] refreshes it
    /// as replicas hot-load/unload versions behind the router
    catalog: RwLock<Vec<ModelInfo>>,
    cfg: RouterConfig,
    /// smooth weighted round-robin credits for single-sample routing
    credits: Mutex<Vec<f64>>,
    started: Instant,
}

impl Router {
    /// Build a router over `replicas`. The model catalog is read from
    /// the first replica that answers (all replicas are expected to
    /// serve the same model set — start the backends before the
    /// router).
    pub fn new(replicas: Vec<Box<dyn Replica>>,
               cfg: RouterConfig) -> Result<Router> {
        ensure!(!replicas.is_empty(),
                "cluster: router needs at least one replica");
        ensure!(
            cfg.hedge_threshold == 0.0 || cfg.hedge_threshold > 1.0,
            "cluster: hedge threshold must be > 1.0 when set \
             (got {}); at or below 1x every shard would be duplicated",
            cfg.hedge_threshold
        );
        let mut catalog: Option<Vec<ModelInfo>> = None;
        let mut last_err: Option<anyhow::Error> = None;
        for r in &replicas {
            match r.model_infos() {
                Ok(c) => {
                    catalog = Some(c);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let catalog = catalog.ok_or_else(|| {
            anyhow!(
                "cluster: no replica answered a model listing \
                 (are the backends up?): {}",
                last_err
                    .map(|e| format!("{e:#}"))
                    .unwrap_or_else(|| "no error".to_string())
            )
        })?;
        // `Arc` so hedge attempts can run detached from the batch that
        // spawned them (a straggler must not block its batch's return)
        let replicas: Vec<Arc<dyn Replica>> =
            replicas.into_iter().map(Arc::from).collect();
        let n = replicas.len();
        Ok(Router {
            replicas,
            states: (0..n)
                .map(|_| Arc::new(ReplicaState::new(cfg.breaker)))
                .collect(),
            totals: TotalCounters {
                submitted: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                shed: AtomicU64::new(0),
                failed: AtomicU64::new(0),
            },
            catalog: RwLock::new(catalog),
            cfg,
            credits: Mutex::new(vec![0.0; n]),
            started: Instant::now(),
        })
    }

    /// The pure partition primitive (see [`split`]); exposed on the
    /// router so call sites and the property tests share one name.
    pub fn split(n: usize, weights: &[f64]) -> Vec<Shard> {
        split(n, weights)
    }

    /// The pure reassembly primitive (see [`merge`]).
    pub fn merge<T: Clone>(
        n: usize,
        parts: &[(Shard, Vec<T>)],
    ) -> std::result::Result<Vec<T>, String> {
        merge(n, parts)
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Snapshot of the cluster catalog (one row per `name@version` a
    /// replica serves; refreshed by [`Router::tick`]).
    pub fn catalog(&self) -> Vec<ModelInfo> {
        self.catalog.read().unwrap().clone()
    }

    /// Replicas whose breaker is closed (the healthy steady state).
    pub fn healthy_replicas(&self) -> usize {
        self.states
            .iter()
            .filter(|s| s.breaker.is_closed())
            .count()
    }

    /// Force-probe every replica — backoff windows included — and feed
    /// each outcome to its breaker; returns how many answered. The
    /// on-demand escape hatch (tests, the all-replicas-down fallback);
    /// periodic probing should use [`Router::tick`], which respects
    /// breaker backoff.
    pub fn check_health(&self) -> usize {
        let mut healthy = 0usize;
        for (r, st) in self.replicas.iter().zip(&self.states) {
            if r.check_health() {
                st.breaker.record_success();
                healthy += 1;
            } else {
                st.breaker.record_failure();
            }
        }
        if self.cfg.metrics_weights {
            self.refresh_remote_hints();
        }
        healthy
    }

    /// Backoff-respecting periodic probe: closed and half-open replicas
    /// are probed (a half-open success closes the breaker; a failure
    /// doubles its backoff), open replicas are left alone until their
    /// window expires. Returns how many replicas were probed.
    pub fn tick(&self) -> usize {
        let mut probed = 0usize;
        for (r, st) in self.replicas.iter().zip(&self.states) {
            if st.breaker.state() == BreakerState::Open {
                continue;
            }
            probed += 1;
            if r.check_health() {
                st.breaker.record_success();
            } else {
                st.breaker.record_failure();
            }
        }
        if self.cfg.metrics_weights {
            self.refresh_remote_hints();
        }
        self.refresh_catalog();
        probed
    }

    /// Re-read the model catalog from the first replica that answers,
    /// so versions hot-loaded (or unloaded, or re-defaulted) on the
    /// backends become routable without restarting the router. Probe-
    /// cadence work ([`Router::tick`]), never on the dispatch path; a
    /// fleet that answers nothing keeps the last-known catalog.
    fn refresh_catalog(&self) {
        for r in &self.replicas {
            if let Ok(c) = r.model_infos() {
                if !c.is_empty() {
                    *self.catalog.write().unwrap() = c;
                }
                return;
            }
        }
    }

    /// Pull each replica's self-published service-time estimate (its
    /// `/metrics` rows) into the weighting state. Probe-cadence work,
    /// never on the dispatch path.
    fn refresh_remote_hints(&self) {
        for (r, st) in self.replicas.iter().zip(&self.states) {
            if let Some(ms) = r.metrics_hint_ms() {
                if ms.is_finite() && ms >= 0.0 {
                    st.remote_ewma_ms
                        .store(ms.to_bits(), Ordering::Relaxed);
                }
            }
        }
    }

    /// Route one sample (the HTTP front's shape).
    pub fn predict_one(
        &self,
        model: &str,
        sample: &[f32],
        deadline: Option<Instant>,
    ) -> SampleResult {
        self.predict_batch(model, &[sample], deadline)
            .pop()
            .expect("one sample in, one result out")
    }

    /// Route a batch: shard the sample dimension across healthy
    /// replicas, fail over around errors, and return per-sample results
    /// in request order. Never panics on replica failure; every sample
    /// gets exactly one result.
    pub fn predict_batch(
        &self,
        model: &str,
        samples: &[&[f32]],
        deadline: Option<Instant>,
    ) -> Vec<SampleResult> {
        let n = samples.len();
        self.totals.submitted.fetch_add(n as u64, Ordering::Relaxed);
        let mut results: Vec<Option<SampleResult>> =
            (0..n).map(|_| None).collect();

        // resolve `name` or `name@version` against the catalog; an
        // unqualified name takes the default row (first row as a
        // fallback for pre-versioning replicas). The ORIGINAL `model`
        // string travels to the replicas untouched, so a versioned
        // request stays versioned on every shard hop.
        let (base, want) = split_versioned(model);
        let info = {
            let cat = self.catalog.read().unwrap();
            match want {
                Some(v) => cat
                    .iter()
                    .find(|i| i.name == base && i.version == v)
                    .cloned(),
                None => cat
                    .iter()
                    .find(|i| i.name == base && i.default)
                    .cloned()
                    .or_else(|| {
                        cat.iter().find(|i| i.name == base).cloned()
                    }),
            }
        };
        let Some(info) = info else {
            let err = RouteError::UnknownModel(format!(
                "unknown model `{model}` (cluster serves: {:?})",
                self.catalog
                    .read()
                    .unwrap()
                    .iter()
                    .map(|i| i.qualified())
                    .collect::<Vec<_>>()
            ));
            let out: Vec<_> =
                (0..n).map(|_| Err(err.clone())).collect();
            self.account(&out);
            return out;
        };
        // validate lengths locally so malformed samples never burn a
        // replica round trip (and never trigger failover)
        let expect: usize = info.input.iter().product();
        let mut pending: Vec<usize> = Vec::with_capacity(n);
        for (i, s) in samples.iter().enumerate() {
            if s.len() == expect {
                pending.push(i);
            } else {
                results[i] = Some(Err(RouteError::BadInput(format!(
                    "sample holds {} values, model `{model}` expects \
                     {expect} (input dims {:?})",
                    s.len(),
                    info.input
                ))));
            }
        }
        // the same seam the single-process batcher caps on: plans whose
        // outputs depend on batch composition shard at batch 1
        let max_shard = if info.batch_invariant {
            self.cfg.max_shard.max(1)
        } else {
            1
        };

        let mut excluded = vec![false; self.replicas.len()];
        // last admission refusal seen this batch: a 429 from one
        // replica reflects that replica's congestion, so the shard is
        // retried on survivors; only if every live replica refuses (or
        // none is left) does the client see the 429
        let mut rejection: Option<String> = None;
        let mut rounds = 0usize;
        while !pending.is_empty() {
            // a spent deadline sheds everything still unanswered
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    for &i in &pending {
                        results[i] = Some(Err(RouteError::Deadline(
                            "client deadline spent while routing"
                                .to_string(),
                        )));
                    }
                    break;
                }
            }
            rounds += 1;
            if rounds > self.replicas.len() + 1 {
                for &i in &pending {
                    results[i] = Some(Err(match &rejection {
                        Some(m) => RouteError::Rejected(m.clone()),
                        None => RouteError::Failed(
                            "no replica could serve the shard after \
                             exhausting failover"
                                .to_string(),
                        ),
                    }));
                }
                break;
            }
            let mut weights = self.weights(&excluded);
            if weights.iter().all(|&w| w <= 0.0) {
                // everyone is shunned or failed this batch already:
                // force-probe for recoveries once, then give up
                self.check_health();
                weights = self.weights(&excluded);
                if weights.iter().all(|&w| w <= 0.0) {
                    for &i in &pending {
                        results[i] = Some(Err(match &rejection {
                            Some(m) => {
                                RouteError::Rejected(m.clone())
                            }
                            None => RouteError::AllReplicasDown(
                                format!(
                                    "all {} replicas are down or \
                                     failed this batch",
                                    self.replicas.len()
                                ),
                            ),
                        }));
                    }
                    break;
                }
            }
            let shards = if pending.len() == 1 {
                // single-sample fast path: smooth weighted round-robin
                // spreads load; a proportional split of n=1 would pin
                // every request to the momentarily-fastest replica
                vec![Shard {
                    replica: self.pick(&weights),
                    start: 0,
                    len: 1,
                }]
            } else {
                chunk(&split(pending.len(), &weights), max_shard)
            };
            let shard_inputs: Vec<Vec<&[f32]>> = shards
                .iter()
                .map(|sh| {
                    pending[sh.start..sh.end()]
                        .iter()
                        .map(|&i| samples[i])
                        .collect()
                })
                .collect();
            let mut outcomes: Vec<Option<ShardResult>> =
                (0..shards.len()).map(|_| None).collect();
            {
                let excl = &excluded;
                if shards.len() == 1 {
                    outcomes[0] = Some(self.dispatch_shard(
                        &shards[0],
                        model,
                        &shard_inputs[0],
                        deadline,
                        excl,
                    ));
                } else {
                    std::thread::scope(|sc| {
                        for ((sh, input), slot) in shards
                            .iter()
                            .zip(&shard_inputs)
                            .zip(outcomes.iter_mut())
                        {
                            sc.spawn(move || {
                                *slot = Some(self.dispatch_shard(
                                    sh, model, input, deadline, excl,
                                ));
                            });
                        }
                    });
                }
            }
            // scatter shard outcomes back through the pending map —
            // the failover-aware form of `merge` (each shard's row j is
            // sample `pending[start + j]` of the original order)
            let mut next_pending: Vec<usize> = Vec::new();
            for (sh, outcome) in shards.iter().zip(outcomes) {
                let idxs = &pending[sh.start..sh.end()];
                match outcome.expect("every shard ran") {
                    Ok(rows) => {
                        for (&i, row) in idxs.iter().zip(rows) {
                            results[i] = Some(Ok(row));
                        }
                    }
                    Err(ReplicaError::Failed(_)) => {
                        excluded[sh.replica] = true;
                        next_pending.extend_from_slice(idxs);
                    }
                    Err(ReplicaError::Rejected(m)) => {
                        // this replica's queue cannot make the
                        // deadline; an idle survivor still might —
                        // retry there (replica stays healthy)
                        excluded[sh.replica] = true;
                        rejection = Some(m);
                        next_pending.extend_from_slice(idxs);
                    }
                    Err(ReplicaError::Deadline(m)) => {
                        for &i in idxs {
                            results[i] =
                                Some(Err(RouteError::Deadline(
                                    m.clone(),
                                )));
                        }
                    }
                    Err(ReplicaError::BadRequest(m)) => {
                        for &i in idxs {
                            results[i] = Some(Err(
                                RouteError::BadInput(m.clone()),
                            ));
                        }
                    }
                }
            }
            next_pending.sort_unstable();
            pending = next_pending;
        }
        let out: Vec<_> = results
            .into_iter()
            .map(|r| r.expect("every sample resolved"))
            .collect();
        self.account(&out);
        out
    }

    /// Run one shard, hedged or plain per config.
    fn dispatch_shard(
        &self,
        sh: &Shard,
        model: &str,
        input: &[&[f32]],
        deadline: Option<Instant>,
        excluded: &[bool],
    ) -> ShardResult {
        if self.cfg.hedge_threshold > 0.0 {
            self.run_shard_hedged(sh, model, input, deadline, excluded)
        } else {
            self.run_shard(sh, model, input, deadline)
        }
    }

    /// Dispatch one shard inline and keep the replica's state current
    /// (the hedging-disabled path: no thread, no sample copies).
    fn run_shard(
        &self,
        sh: &Shard,
        model: &str,
        input: &[&[f32]],
        deadline: Option<Instant>,
    ) -> ShardResult {
        let st = &self.states[sh.replica];
        st.shards.fetch_add(1, Ordering::Relaxed);
        st.inflight.fetch_add(1, Ordering::Relaxed);
        let t = Timer::start();
        let r = self.replicas[sh.replica]
            .predict_shard(model, input, deadline)
            .and_then(|rows| {
                if rows.len() == input.len() {
                    Ok(rows)
                } else {
                    Err(ReplicaError::Failed(format!(
                        "replica `{}` answered {} rows for {} samples",
                        self.replicas[sh.replica].name(),
                        rows.len(),
                        input.len()
                    )))
                }
            });
        match &r {
            Ok(rows) => {
                st.samples
                    .fetch_add(rows.len() as u64, Ordering::Relaxed);
                let per_sample_ms =
                    t.elapsed_ms() / input.len().max(1) as f64;
                st.observe(per_sample_ms);
                st.breaker.record_success();
            }
            Err(ReplicaError::Failed(_)) => {
                st.failed_shards.fetch_add(1, Ordering::Relaxed);
                st.rerouted
                    .fetch_add(input.len() as u64, Ordering::Relaxed);
                st.breaker.record_failure();
            }
            Err(_) => {
                // deadline- or request-shaped: the replica is fine
            }
        }
        st.inflight.fetch_sub(1, Ordering::Relaxed);
        r
    }

    /// Dispatch one attempt of a hedged shard on a detached thread.
    /// The thread owns `Arc` clones of the replica and its state, so a
    /// straggler keeps running (and keeps its EWMA/breaker accounting)
    /// after the batch that spawned it has returned; its send simply
    /// finds the receiver gone. `samples` is deliberately NOT bumped
    /// here — only the winning completion counts, which is what keeps
    /// per-sample accounting exact under duplication.
    fn spawn_attempt(
        &self,
        idx: usize,
        model: &str,
        input: &[&[f32]],
        deadline: Option<Instant>,
        tx: mpsc::Sender<(usize, ShardResult)>,
    ) {
        let replica = Arc::clone(&self.replicas[idx]);
        let st = Arc::clone(&self.states[idx]);
        let model = model.to_string();
        let owned: Vec<Vec<f32>> =
            input.iter().map(|s| s.to_vec()).collect();
        std::thread::spawn(move || {
            st.shards.fetch_add(1, Ordering::Relaxed);
            st.inflight.fetch_add(1, Ordering::Relaxed);
            let t = Timer::start();
            let refs: Vec<&[f32]> =
                owned.iter().map(|v| v.as_slice()).collect();
            let r = replica
                .predict_shard(&model, &refs, deadline)
                .and_then(|rows| {
                    if rows.len() == refs.len() {
                        Ok(rows)
                    } else {
                        Err(ReplicaError::Failed(format!(
                            "replica `{}` answered {} rows for {} \
                             samples",
                            replica.name(),
                            rows.len(),
                            refs.len()
                        )))
                    }
                });
            match &r {
                Ok(_) => {
                    st.observe(
                        t.elapsed_ms() / refs.len().max(1) as f64,
                    );
                    st.breaker.record_success();
                }
                Err(ReplicaError::Failed(_)) => {
                    st.failed_shards.fetch_add(1, Ordering::Relaxed);
                    st.breaker.record_failure();
                }
                Err(_) => {}
            }
            st.inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = tx.send((idx, r));
        });
    }

    /// The fastest idle eligible replica to duplicate a straggling
    /// shard onto; `None` when no replica is idle (hedging onto a busy
    /// replica would just lengthen someone else's tail).
    fn pick_hedge(
        &self,
        primary: usize,
        excluded: &[bool],
    ) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, st) in self.states.iter().enumerate() {
            if i == primary
                || excluded[i]
                || !st.breaker.admits()
                || st.inflight.load(Ordering::Relaxed) > 0
            {
                continue;
            }
            // 0.0 = no estimate = optimistic, same as the weighting
            let ms = self.estimate_ms(i);
            match best {
                Some((_, b)) if ms >= b => {}
                _ => best = Some((i, ms)),
            }
        }
        best.map(|(i, _)| i)
    }

    /// Run one shard with hedging: dispatch to the picked replica, and
    /// if no completion lands within `hedge_threshold ×` its expected
    /// shard time (floored at `hedge_min_ms`), duplicate the shard on
    /// the fastest idle survivor. The first completion wins; an error
    /// completion waits for the in-flight duplicate (it can only
    /// improve the outcome). The loser's result is discarded and its
    /// `samples` are never counted.
    fn run_shard_hedged(
        &self,
        sh: &Shard,
        model: &str,
        input: &[&[f32]],
        deadline: Option<Instant>,
        excluded: &[bool],
    ) -> ShardResult {
        let (tx, rx) = mpsc::channel::<(usize, ShardResult)>();
        self.spawn_attempt(sh.replica, model, input, deadline,
                           tx.clone());
        // no estimate yet -> no trigger: hedging needs a baseline to
        // call the primary a straggler against
        let est = self.estimate_ms(sh.replica);
        let trigger_ms = if est > 0.0 {
            (est * input.len() as f64 * self.cfg.hedge_threshold)
                .max(self.cfg.hedge_min_ms)
        } else {
            -1.0
        };
        let mut first: Option<(usize, ShardResult)> = None;
        if trigger_ms > 0.0 {
            if let Ok(c) = rx.recv_timeout(Duration::from_secs_f64(
                trigger_ms / 1e3,
            )) {
                first = Some(c);
            }
        }
        let mut hedge: Option<usize> = None;
        if first.is_none() && trigger_ms > 0.0 {
            if let Some(h) = self.pick_hedge(sh.replica, excluded) {
                self.states[h]
                    .hedges
                    .fetch_add(1, Ordering::Relaxed);
                self.spawn_attempt(h, model, input, deadline,
                                   tx.clone());
                hedge = Some(h);
            }
        }
        // every attempt is in flight; drop our sender so `recv`
        // disconnects (instead of hanging) if an attempt thread dies
        drop(tx);
        let mut used = match first {
            Some(c) => Some(c),
            None => rx.recv().ok(),
        };
        if hedge.is_some() {
            let retryable = matches!(
                used,
                Some((_, Err(ReplicaError::Failed(_))))
                    | Some((_, Err(ReplicaError::Rejected(_))))
            );
            if retryable {
                // the other attempt is still running — its answer can
                // only improve on an error
                if let Ok(second) = rx.recv() {
                    if second.1.is_ok() {
                        used = Some(second);
                    }
                }
            }
        }
        let (winner, result) = used.unwrap_or_else(|| {
            (
                sh.replica,
                Err(ReplicaError::Failed(
                    "hedged shard: no attempt completed (dispatch \
                     thread died)"
                        .to_string(),
                )),
            )
        });
        if let Some(h) = hedge {
            let hst = &self.states[h];
            if winner == h {
                hst.hedge_wins.fetch_add(1, Ordering::Relaxed);
            } else {
                hst.hedge_losses.fetch_add(1, Ordering::Relaxed);
            }
        }
        // exactly-once accounting: only the completion actually used
        // counts samples (or, on failure, samples-to-reroute)
        let wst = &self.states[winner];
        match &result {
            Ok(rows) => {
                wst.samples
                    .fetch_add(rows.len() as u64, Ordering::Relaxed);
            }
            Err(ReplicaError::Failed(_)) => {
                wst.rerouted
                    .fetch_add(input.len() as u64, Ordering::Relaxed);
            }
            Err(_) => {}
        }
        // failover bookkeeping keys off the shard's assigned replica;
        // if the hedge won, the straggling primary should be the one
        // excluded for the rest of the batch, so rewrite is unneeded:
        // `sh.replica` IS the primary in every Err path that excludes
        result
    }

    /// Best per-sample ms estimate for one replica: the replica's own
    /// published `/metrics` figure when `metrics_weights` is set, then
    /// the router's observed EWMA, then the replica's inline hint.
    /// 0.0 = nothing known.
    fn estimate_ms(&self, i: usize) -> f64 {
        let st = &self.states[i];
        if self.cfg.metrics_weights {
            let remote = st.remote_ms();
            if remote > 0.0 {
                return remote;
            }
        }
        let own = st.ewma_ms();
        if own > 0.0 {
            return own;
        }
        self.replicas[i].ewma_hint_ms().unwrap_or(0.0)
    }

    /// Per-replica shard weights: reciprocal estimated per-sample speed
    /// (see [`Router::estimate_ms`] for the estimate order). A replica
    /// with no estimate at all is optimistic — it weighs like the
    /// fastest measured one — so it keeps receiving traffic and earns
    /// an estimate instead of starving next to a measured-fast sibling.
    /// Excluded replicas and replicas inside their breaker's backoff
    /// window weigh 0 (a half-open replica is eligible: live traffic is
    /// its trial).
    fn weights(&self, excluded: &[bool]) -> Vec<f64> {
        // per-replica ms estimate; -1 = ineligible, 0 = unknown
        let ms: Vec<f64> = self
            .states
            .iter()
            .enumerate()
            .map(|(i, st)| {
                if excluded[i] || !st.breaker.admits() {
                    return -1.0;
                }
                self.estimate_ms(i)
            })
            .collect();
        let fastest = ms
            .iter()
            .filter(|&&m| m > 0.0)
            .fold(f64::INFINITY, |a, &b| a.min(b));
        ms.into_iter()
            .map(|m| {
                if m < 0.0 {
                    return 0.0;
                }
                let est = if m > 0.0 { m } else { fastest };
                if est.is_finite() {
                    1.0 / est.max(1e-3)
                } else {
                    1.0
                }
            })
            .collect()
    }

    /// One smooth-WRR pick under the credits lock (see [`smooth_wrr`]).
    fn pick(&self, weights: &[f64]) -> usize {
        let mut credits = self.credits.lock().unwrap();
        smooth_wrr(credits.as_mut_slice(), weights)
    }

    /// Bump the outcome buckets for one answered batch.
    fn account(&self, results: &[SampleResult]) {
        let (mut done, mut rej, mut shed, mut failed) =
            (0u64, 0u64, 0u64, 0u64);
        for r in results {
            match r {
                Ok(_) => done += 1,
                Err(RouteError::Rejected(_)) => rej += 1,
                Err(RouteError::Deadline(_)) => shed += 1,
                Err(_) => failed += 1,
            }
        }
        self.totals.completed.fetch_add(done, Ordering::Relaxed);
        self.totals.rejected.fetch_add(rej, Ordering::Relaxed);
        self.totals.shed.fetch_add(shed, Ordering::Relaxed);
        self.totals.failed.fetch_add(failed, Ordering::Relaxed);
    }

    /// Live sample accounting snapshot.
    pub fn totals(&self) -> ClusterTotals {
        ClusterTotals {
            submitted: self.totals.submitted.load(Ordering::Relaxed),
            completed: self.totals.completed.load(Ordering::Relaxed),
            rejected: self.totals.rejected.load(Ordering::Relaxed),
            shed: self.totals.shed.load(Ordering::Relaxed),
            failed: self.totals.failed.load(Ordering::Relaxed),
        }
    }

    /// Live per-replica routing reports (replica order).
    pub fn reports(&self) -> Vec<ReplicaReport> {
        let elapsed =
            self.started.elapsed().as_secs_f64().max(1e-9);
        self.replicas
            .iter()
            .zip(&self.states)
            .map(|(r, st)| ReplicaReport {
                replica: r.name().to_string(),
                healthy: st.breaker.is_closed(),
                breaker_state: st.breaker.state().name(),
                breaker_trips: st.breaker.trips(),
                shards: st.shards.load(Ordering::Relaxed),
                samples: st.samples.load(Ordering::Relaxed),
                failed_shards: st
                    .failed_shards
                    .load(Ordering::Relaxed),
                rerouted: st.rerouted.load(Ordering::Relaxed),
                hedges: st.hedges.load(Ordering::Relaxed),
                hedge_wins: st.hedge_wins.load(Ordering::Relaxed),
                hedge_losses: st
                    .hedge_losses
                    .load(Ordering::Relaxed),
                ewma_sample_ms: st.ewma_ms(),
                images_per_sec: st.samples.load(Ordering::Relaxed)
                    as f64
                    / elapsed,
            })
            .collect()
    }

    /// Append the cluster totals row plus one JSONL event per replica
    /// to a metrics log (rides next to the `serve_model` rows).
    pub fn log_to(&self, metrics: &mut Metrics) -> std::io::Result<()> {
        metrics.record_custom(self.totals().to_json())?;
        for r in self.reports() {
            metrics.record_custom(r.to_json())?;
        }
        Ok(())
    }
}

impl ServeBackend for Router {
    fn healthz(&self) -> (u16, Json) {
        let total = self.replicas.len();
        let healthy = self.healthy_replicas();
        let status = if healthy == total {
            "ok"
        } else if healthy > 0 {
            "degraded"
        } else {
            "down"
        };
        (
            if healthy > 0 { 200 } else { 503 },
            Json::obj(vec![
                ("status", Json::str(status)),
                ("models",
                 Json::num(self.catalog.read().unwrap().len() as f64)),
                ("replicas", Json::num(total as f64)),
                ("replicas_healthy", Json::num(healthy as f64)),
            ]),
        )
    }

    fn infos(&self) -> Vec<ModelInfo> {
        self.catalog()
    }

    fn metric_rows(&self) -> Vec<Json> {
        let mut rows = vec![self.totals().to_json()];
        rows.extend(self.reports().iter().map(|r| r.to_json()));
        rows
    }

    fn predict(
        &self,
        model: &str,
        input: &[f32],
        deadline: Option<Instant>,
    ) -> std::result::Result<Vec<f32>, PredictError> {
        self.predict_one(model, input, deadline).map_err(|e| match e {
            RouteError::UnknownModel(m) => {
                PredictError::UnknownModel(m)
            }
            RouteError::BadInput(m) => PredictError::BadInput(m),
            RouteError::Rejected(m) | RouteError::Deadline(m) => {
                PredictError::Deadline(m)
            }
            RouteError::AllReplicasDown(m) => {
                PredictError::Unavailable("no_healthy_replicas", m)
            }
            RouteError::Failed(m) => PredictError::Failed(m),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::replica::InProcessReplica;
    use super::*;
    use crate::infer::{ExecMode, KernelBackend, Plan, PlanOptions};
    use crate::serve::{Registry, Server, ServerConfig};
    use crate::testkit::models::synth_mlp_model;
    use std::sync::Arc;
    use std::time::Duration;

    fn shared_plan() -> Arc<Plan> {
        let (graph, model) = synth_mlp_model(4);
        Arc::new(
            Plan::compile(
                &graph,
                &model,
                PlanOptions {
                    mode: ExecMode::LutTrick,
                    act_bits: 0,
                    mlbn: false,
                    threads: 1,
                    kernel: KernelBackend::Scalar,
                },
                &[16],
            )
            .unwrap(),
        )
    }

    fn in_process(plan: &Arc<Plan>) -> (Arc<Server>, Box<dyn Replica>) {
        let mut reg = Registry::new();
        reg.register_shared("mlp", Arc::clone(plan)).unwrap();
        let server = Arc::new(
            Server::start(
                reg,
                ServerConfig {
                    workers: 1,
                    max_batch: 4,
                    linger: Duration::from_millis(1),
                    queue_cap: 64,
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        let rep: Box<dyn Replica> = Box::new(InProcessReplica::new(
            "r",
            Arc::clone(&server),
        ));
        (server, rep)
    }

    #[test]
    fn router_requires_a_replica_and_a_catalog() {
        assert!(Router::new(Vec::new(), RouterConfig::default())
            .is_err());
    }

    #[test]
    fn router_rejects_hedge_threshold_at_or_below_one() {
        let plan = shared_plan();
        for bad in [0.5, 1.0] {
            let (_srv, rep) = in_process(&plan);
            let cfg = RouterConfig {
                hedge_threshold: bad,
                ..RouterConfig::default()
            };
            assert!(Router::new(vec![rep], cfg).is_err(),
                    "threshold {bad} must be rejected");
        }
    }

    #[test]
    fn unknown_model_and_bad_input_fail_without_touching_replicas() {
        let plan = shared_plan();
        let (_srv, rep) = in_process(&plan);
        let router =
            Router::new(vec![rep], RouterConfig::default()).unwrap();
        let sample = vec![0.0f32; 16];
        assert!(matches!(
            router.predict_one("nope", &sample, None),
            Err(RouteError::UnknownModel(_))
        ));
        assert!(matches!(
            router.predict_one("mlp", &[0.0; 3], None),
            Err(RouteError::BadInput(_))
        ));
        let t = router.totals();
        assert!(t.reconciles(), "{t:?}");
        assert_eq!(t.failed, 2);
        // no shard was ever dispatched
        assert_eq!(router.reports()[0].shards, 0);
    }

    #[test]
    fn weighted_round_robin_spreads_singles() {
        let plan = shared_plan();
        let (_s0, r0) = in_process(&plan);
        let (_s1, r1) = in_process(&plan);
        let router =
            Router::new(vec![r0, r1], RouterConfig::default()).unwrap();
        let sample = vec![0.5f32; 16];
        for _ in 0..32 {
            router.predict_one("mlp", &sample, None).unwrap();
        }
        // exact shares depend on measured speeds, but a healthy
        // replica must never starve, and nothing is served twice
        let reports = router.reports();
        assert!(reports[0].samples > 0, "{reports:?}");
        assert!(reports[1].samples > 0, "{reports:?}");
        assert_eq!(reports[0].samples + reports[1].samples, 32);
        assert!(router.totals().reconciles());
    }

    #[test]
    fn smooth_wrr_matches_weight_shares_exactly() {
        let weights = [3.0, 1.0];
        let mut credits = vec![0.0; 2];
        let mut counts = [0usize; 2];
        let picks: Vec<usize> = (0..40)
            .map(|_| smooth_wrr(&mut credits, &weights))
            .collect();
        for &p in &picks {
            counts[p] += 1;
        }
        // a 3:1 weighting serves exactly 3:1 over full rounds
        assert_eq!(counts, [30, 10], "{picks:?}");
        // and spreads: the light replica appears once in every round
        // of four, never starved to the end of a window
        for round in picks.chunks(4) {
            assert_eq!(
                round.iter().filter(|&&p| p == 1).count(),
                1,
                "{picks:?}"
            );
        }
    }

    #[test]
    fn smooth_wrr_interleaves_instead_of_bursting() {
        let weights = [2.0, 1.0, 1.0];
        let mut credits = vec![0.0; 3];
        let picks: Vec<usize> = (0..24)
            .map(|_| smooth_wrr(&mut credits, &weights))
            .collect();
        // smoothness: the heavy replica never serves more than twice
        // in a row even though it owns half the traffic
        let mut run = 0usize;
        for &p in &picks {
            run = if p == 0 { run + 1 } else { 0 };
            assert!(run <= 2, "replica 0 burst in {picks:?}");
        }
        let c0 = picks.iter().filter(|&&p| p == 0).count();
        assert_eq!(c0, 12, "{picks:?}");
    }

    #[test]
    fn smooth_wrr_never_picks_zero_weight() {
        let weights = [0.0, 1.0, 2.0];
        let mut credits = vec![0.0; 3];
        let mut counts = [0usize; 3];
        for _ in 0..30 {
            counts[smooth_wrr(&mut credits, &weights)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 2 * counts[1]);
    }

    #[test]
    fn versioned_references_route_and_tick_refreshes_catalog() {
        let plan = shared_plan();
        let (srv, rep) = in_process(&plan);
        let router =
            Router::new(vec![rep], RouterConfig::default()).unwrap();
        assert_eq!(router.catalog().len(), 1);
        let sample = vec![0.25f32; 16];
        // an explicit @v1 resolves to the same row as the default
        let a = router.predict_one("mlp@v1", &sample, None).unwrap();
        let b = router.predict_one("mlp", &sample, None).unwrap();
        assert_eq!(a, b);
        // unknown versions 404 with qualified names in the message
        match router.predict_one("mlp@v9", &sample, None) {
            Err(RouteError::UnknownModel(m)) => {
                assert!(m.contains("mlp@v1"), "{m}")
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        // hot-load v2 on the backend; a tick makes it routable
        let (graph, model) = synth_mlp_model(8);
        let v2 = Arc::new(
            Plan::compile(
                &graph,
                &model,
                PlanOptions {
                    mode: ExecMode::LutTrick,
                    act_bits: 0,
                    mlbn: false,
                    threads: 1,
                    kernel: KernelBackend::Scalar,
                },
                &[16],
            )
            .unwrap(),
        );
        srv.load_version("mlp", "v2", v2).unwrap();
        router.tick();
        assert_eq!(router.catalog().len(), 2);
        let c = router.predict_one("mlp@v2", &sample, None).unwrap();
        assert_eq!(c.len(), 10);
        // different weights: v2 must not answer v1's logits
        assert_ne!(a, c);
        assert!(router.totals().reconciles());
    }

    #[test]
    fn serve_backend_face_matches_cluster_state() {
        let plan = shared_plan();
        let (_s0, r0) = in_process(&plan);
        let router =
            Router::new(vec![r0], RouterConfig::default()).unwrap();
        let (code, body) = router.healthz();
        assert_eq!(code, 200);
        assert_eq!(body.at("status").as_str(), Some("ok"));
        assert_eq!(body.at("replicas_healthy").as_usize(), Some(1));
        assert_eq!(ServeBackend::infos(&router).len(), 1);
        let rows = router.metric_rows();
        assert_eq!(rows[0].at("event").as_str(),
                   Some("serve_cluster"));
        assert_eq!(rows[1].at("event").as_str(),
                   Some("serve_replica"));
        assert_eq!(rows[1].at("breaker_state").as_str(),
                   Some("closed"));
        let out = ServeBackend::predict(
            &router,
            "mlp",
            &[0.25; 16],
            None,
        )
        .unwrap();
        assert_eq!(out.len(), 10);
    }
}
