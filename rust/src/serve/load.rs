//! Closed-loop serving load harness, shared by `lutq serve-bench` and
//! the `infer_engine` bench so the two serving measurements cannot
//! silently diverge.
//!
//! `clients` threads pull request indices from one atomic counter and
//! each submit a single-image request (round-robin over `model_ids`,
//! cycling through that model's sample pool), blocking for the reply
//! before taking the next index. Closed-loop callers bound the number of
//! in-flight requests, so pick `clients` at least 2x the coalescing cap
//! if batches should fill.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::util::Timer;

use super::server::Server;

/// Shared per-model pools of single-image samples:
/// `pools[model_id][sample_idx]`.
pub type SamplePools = Arc<Vec<Vec<Vec<f32>>>>;

/// Drive `total` requests through `server` and return per-request
/// `(model_id, latency_ms)` pairs plus the wall-clock seconds of the
/// whole run (for sustained images/sec).
pub fn closed_loop(server: &Arc<Server>, model_ids: &[usize],
                   pools: &SamplePools, total: usize,
                   clients: usize) -> Result<(Vec<(usize, f32)>, f64)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0));
    }
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let srv = Arc::clone(server);
        let next = Arc::clone(&next);
        let pools = Arc::clone(pools);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<Vec<(usize, f32)>> {
                let mut lat = Vec::new();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % pools[m].len();
                    let t = Timer::start();
                    let out = srv.submit_by_id(m, &pools[m][s])?.wait()?;
                    lat.push((m, t.elapsed_ms() as f32));
                    std::hint::black_box(out.len());
                }
                Ok(lat)
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    for j in joins {
        let lat = j
            .join()
            .map_err(|_| anyhow!("serve load client panicked"))??;
        all.extend(lat);
    }
    Ok((all, wall.elapsed_s()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{ExecMode, Plan, PlanOptions};
    use crate::serve::{Registry, ServerConfig};
    use crate::testkit::models::synth_mlp_model;
    use crate::util::Rng;
    use std::time::Duration;

    #[test]
    fn closed_loop_answers_every_request() {
        let (graph, model) = synth_mlp_model(4);
        let plan = Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.register("mlp", plan).unwrap();
        let server = Arc::new(
            Server::start(reg, ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
            })
            .unwrap(),
        );
        let mut rng = Rng::new(4);
        let pools: SamplePools =
            Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
        let (lat, secs) =
            closed_loop(&server, &[0], &pools, 17, 3).unwrap();
        assert_eq!(lat.len(), 17);
        assert!(lat.iter().all(|(m, ms)| *m == 0 && *ms >= 0.0));
        assert!(secs > 0.0);
        let server = Arc::try_unwrap(server).ok().expect("clients done");
        assert_eq!(server.shutdown()[0].requests, 17);
    }
}
