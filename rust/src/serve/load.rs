//! Closed-loop serving load harness, shared by `lutq serve-bench` and
//! the `infer_engine` bench so the two serving measurements cannot
//! silently diverge.
//!
//! `clients` threads pull request indices from one atomic counter and
//! each submit a single-image request (round-robin over `model_ids`,
//! cycling through that model's sample pool), blocking for the reply
//! before taking the next index. Closed-loop callers bound the number of
//! in-flight requests, so pick `clients` at least 2x the coalescing cap
//! if batches should fill.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::jsonic::Json;
use crate::util::Timer;

use super::cluster::{RouteError, Router};
use super::http::HttpClient;
use super::server::Server;
use super::wire::frame::predict_frame_bytes;
use super::wire::{WireClient, WireReply};

/// Shared per-model pools of single-image samples:
/// `pools[model_id][sample_idx]`.
pub type SamplePools = Arc<Vec<Vec<Vec<f32>>>>;

/// Drive `total` requests through `server` and return per-request
/// `(model_id, latency_ms)` pairs plus the wall-clock seconds of the
/// whole run (for sustained images/sec).
pub fn closed_loop(server: &Arc<Server>, model_ids: &[usize],
                   pools: &SamplePools, total: usize,
                   clients: usize) -> Result<(Vec<(usize, f32)>, f64)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0));
    }
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let srv = Arc::clone(server);
        let next = Arc::clone(&next);
        let pools = Arc::clone(pools);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<Vec<(usize, f32)>> {
                let mut lat = Vec::new();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % pools[m].len();
                    let t = Timer::start();
                    let out = srv.submit_by_id(m, &pools[m][s])?.wait()?;
                    lat.push((m, t.elapsed_ms() as f32));
                    std::hint::black_box(out.len());
                }
                Ok(lat)
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    for j in joins {
        let lat = j
            .join()
            .map_err(|_| anyhow!("serve load client panicked"))??;
        all.extend(lat);
    }
    Ok((all, wall.elapsed_s()))
}

/// Outcome tallies of one HTTP closed-loop run.
#[derive(Debug, Clone, Copy, Default)]
pub struct HttpLoadStats {
    /// 200s — answered with logits
    pub ok: u64,
    /// 429s — rejected at admission or shed in-queue past the deadline
    pub rejected: u64,
    /// any other status (4xx/5xx)
    pub failed: u64,
}

impl HttpLoadStats {
    /// Fraction of requests turned away for deadline reasons.
    pub fn shed_rate(&self) -> f64 {
        let total = self.ok + self.rejected + self.failed;
        if total == 0 {
            0.0
        } else {
            self.rejected as f64 / total as f64
        }
    }
}

/// The [`closed_loop`] harness over the network: `clients` keep-alive
/// HTTP connections drive `total` predict requests against a running
/// [`crate::serve::HttpFront`] at `addr`, round-robin over `model_ids`
/// (named via `names[id]`, sampling `pools[id]`). Request bodies are
/// pre-serialized so the measured path is socket + front + serve stack,
/// not client-side JSON formatting. Latencies are recorded for 200s
/// only; 429s and other failures are tallied in [`HttpLoadStats`].
pub fn closed_loop_http(addr: &str, names: &[String], model_ids: &[usize],
                        pools: &SamplePools, total: usize, clients: usize,
                        deadline_ms: Option<f64>)
                        -> Result<(Vec<(usize, f32)>, f64, HttpLoadStats)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0, HttpLoadStats::default()));
    }
    // one request body per (model, pool sample), serialized once
    let bodies: Arc<Vec<Vec<String>>> = Arc::new(
        pools
            .iter()
            .map(|pool| {
                pool.iter()
                    .map(|s| {
                        format!("{{\"input\":{}}}", Json::from_f32s(s))
                    })
                    .collect()
            })
            .collect(),
    );
    let names: Arc<Vec<String>> = Arc::new(names.to_vec());
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let addr = addr.to_string();
        let next = Arc::clone(&next);
        let bodies = Arc::clone(&bodies);
        let names = Arc::clone(&names);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<(Vec<(usize, f32)>, HttpLoadStats)> {
                let mut client = HttpClient::connect(&addr)?;
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % bodies[m].len();
                    let t = Timer::start();
                    let (status, body) = client.predict(
                        &names[m], &bodies[m][s], deadline_ms)?;
                    match status {
                        200 => {
                            stats.ok += 1;
                            lat.push((m, t.elapsed_ms() as f32));
                        }
                        429 => stats.rejected += 1,
                        _ => stats.failed += 1,
                    }
                    std::hint::black_box(body.len());
                }
                Ok((lat, stats))
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    let mut agg = HttpLoadStats::default();
    for j in joins {
        let (lat, stats) = j
            .join()
            .map_err(|_| anyhow!("serve http load client panicked"))??;
        all.extend(lat);
        agg.ok += stats.ok;
        agg.rejected += stats.rejected;
        agg.failed += stats.failed;
    }
    Ok((all, wall.elapsed_s(), agg))
}

/// The [`closed_loop`] harness over the binary wire protocol:
/// `clients` keep-alive [`WireClient`] connections drive `total`
/// predict requests against a running [`crate::serve::WireServer`] at
/// `addr`, round-robin over `model_ids` (named via `names[id]`,
/// sampling `pools[id]`). Whole predict frames are pre-encoded so the
/// measured path is socket + framing + serve stack with zero
/// per-request encoding — the binary analog of [`closed_loop_http`]'s
/// pre-serialized bodies, and the comparison that quantifies the JSON
/// tax. Outcomes tally into the same [`HttpLoadStats`] buckets so
/// shed-rate rows compare across transports.
pub fn closed_loop_wire(addr: &str, names: &[String], model_ids: &[usize],
                        pools: &SamplePools, total: usize, clients: usize,
                        deadline_ms: Option<f64>)
                        -> Result<(Vec<(usize, f32)>, f64, HttpLoadStats)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0, HttpLoadStats::default()));
    }
    // one complete predict frame per (model, pool sample), encoded once
    let frames: Arc<Vec<Vec<Vec<u8>>>> = Arc::new(
        pools
            .iter()
            .enumerate()
            .map(|(m, pool)| {
                pool.iter()
                    .map(|s| {
                        predict_frame_bytes(
                            &names[m],
                            &[s.as_slice()],
                            deadline_ms,
                        )
                        .map_err(|e| {
                            anyhow!("encode predict frame: {e}")
                        })
                    })
                    .collect::<Result<Vec<Vec<u8>>>>()
            })
            .collect::<Result<Vec<Vec<Vec<u8>>>>>()?,
    );
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let addr = addr.to_string();
        let next = Arc::clone(&next);
        let frames = Arc::clone(&frames);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<(Vec<(usize, f32)>, HttpLoadStats)> {
                let mut client = WireClient::connect(&addr)?;
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % frames[m].len();
                    let t = Timer::start();
                    match client.request_frame(&frames[m][s])? {
                        WireReply::Outputs(rows) => {
                            stats.ok += 1;
                            lat.push((m, t.elapsed_ms() as f32));
                            std::hint::black_box(rows.len());
                        }
                        WireReply::Refused(e) if e.status == 429 => {
                            stats.rejected += 1;
                        }
                        WireReply::Refused(_) => stats.failed += 1,
                    }
                }
                Ok((lat, stats))
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    let mut agg = HttpLoadStats::default();
    for j in joins {
        let (lat, stats) = j
            .join()
            .map_err(|_| anyhow!("serve wire load client panicked"))??;
        all.extend(lat);
        agg.ok += stats.ok;
        agg.rejected += stats.rejected;
        agg.failed += stats.failed;
    }
    Ok((all, wall.elapsed_s(), agg))
}

/// The [`closed_loop`] harness through the cluster router: `clients`
/// threads drive `total` single-sample requests via
/// [`Router::predict_one`], round-robin over `model_ids` (named via
/// `names[id]`, sampling `pools[id]`). Latencies are recorded for
/// completed requests; deadline-shaped refusals and failures are
/// tallied in [`HttpLoadStats`] (same buckets as the HTTP loop, so
/// shed-rate rows compare across transports).
pub fn closed_loop_cluster(router: &Arc<Router>, names: &[String],
                           model_ids: &[usize], pools: &SamplePools,
                           total: usize, clients: usize,
                           deadline: Option<Duration>)
                           -> Result<(Vec<(usize, f32)>, f64,
                                      HttpLoadStats)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0, HttpLoadStats::default()));
    }
    let names: Arc<Vec<String>> = Arc::new(names.to_vec());
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let rt = Arc::clone(router);
        let next = Arc::clone(&next);
        let pools = Arc::clone(pools);
        let names = Arc::clone(&names);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> (Vec<(usize, f32)>, HttpLoadStats) {
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % pools[m].len();
                    let d = deadline.map(|d| Instant::now() + d);
                    let t = Timer::start();
                    match rt.predict_one(&names[m], &pools[m][s], d) {
                        Ok(out) => {
                            stats.ok += 1;
                            lat.push((m, t.elapsed_ms() as f32));
                            std::hint::black_box(out.len());
                        }
                        Err(RouteError::Rejected(_))
                        | Err(RouteError::Deadline(_)) => {
                            stats.rejected += 1;
                        }
                        Err(_) => stats.failed += 1,
                    }
                }
                (lat, stats)
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    let mut agg = HttpLoadStats::default();
    for j in joins {
        let (lat, stats) = j
            .join()
            .map_err(|_| anyhow!("cluster load client panicked"))?;
        all.extend(lat);
        agg.ok += stats.ok;
        agg.rejected += stats.rejected;
        agg.failed += stats.failed;
    }
    Ok((all, wall.elapsed_s(), agg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{ExecMode, Plan, PlanOptions};
    use crate::serve::{Registry, ServerConfig};
    use crate::testkit::models::synth_mlp_model;
    use crate::util::Rng;
    use std::time::Duration;

    #[test]
    fn closed_loop_answers_every_request() {
        let (graph, model) = synth_mlp_model(4);
        let plan = Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.register("mlp", plan).unwrap();
        let server = Arc::new(
            Server::start(reg, ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
            })
            .unwrap(),
        );
        let mut rng = Rng::new(4);
        let pools: SamplePools =
            Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
        let (lat, secs) =
            closed_loop(&server, &[0], &pools, 17, 3).unwrap();
        assert_eq!(lat.len(), 17);
        assert!(lat.iter().all(|(m, ms)| *m == 0 && *ms >= 0.0));
        assert!(secs > 0.0);
        let server = Arc::try_unwrap(server).ok().expect("clients done");
        assert_eq!(server.shutdown()[0].requests, 17);
    }
}
