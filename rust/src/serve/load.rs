//! Serving load harnesses, shared by `lutq serve-bench` and the
//! `infer_engine` bench so the serving measurements cannot silently
//! diverge. Two request disciplines:
//!
//! * **Closed loop** (`closed_loop*`): `clients` threads pull request
//!   indices from one atomic counter and each submit a single-image
//!   request (round-robin over `model_ids`, cycling through that
//!   model's sample pool), blocking for the reply before taking the
//!   next index. Closed-loop callers bound the number of in-flight
//!   requests, so pick `clients` at least 2x the coalescing cap if
//!   batches should fill. Closed loops measure service time, but they
//!   *slow down when the server slows down* — they cannot show what an
//!   independent client population experiences under overload.
//! * **Open loop** (`open_loop*`): an [`Arrival`] schedule fixes every
//!   request's send time *before the run starts* (Poisson, bursty
//!   square-wave, or recorded-trace replay, all seeded through
//!   [`crate::util::Rng`] like `testkit::flaky`). Latency is measured
//!   from the *scheduled* arrival, not from when a worker got around to
//!   sending — a backed-up server makes every subsequent request look
//!   slower, exactly as real clients would see it. This avoids the
//!   coordinated-omission trap and is what the latency-under-SLO rows
//!   ([`OpenLoopReport::slo_curve`]) are built from.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::jsonic::Json;
use crate::util::{Rng, Timer};

use super::batcher::ReplyError;
use super::cluster::{RouteError, Router};
use super::http::HttpClient;
use super::server::Server;
use super::wire::frame::predict_frame_bytes;
use super::wire::{WireClient, WireReply};

/// Shared per-model pools of single-image samples:
/// `pools[model_id][sample_idx]`.
pub type SamplePools = Arc<Vec<Vec<Vec<f32>>>>;

/// Drive `total` requests through `server` and return per-request
/// `(model_id, latency_ms)` pairs plus the wall-clock seconds of the
/// whole run (for sustained images/sec).
pub fn closed_loop(server: &Arc<Server>, model_ids: &[usize],
                   pools: &SamplePools, total: usize,
                   clients: usize) -> Result<(Vec<(usize, f32)>, f64)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0));
    }
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let srv = Arc::clone(server);
        let next = Arc::clone(&next);
        let pools = Arc::clone(pools);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<Vec<(usize, f32)>> {
                let mut lat = Vec::new();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % pools[m].len();
                    let t = Timer::start();
                    let out = srv.submit_by_id(m, &pools[m][s])?.wait()?;
                    lat.push((m, t.elapsed_ms() as f32));
                    std::hint::black_box(out.len());
                }
                Ok(lat)
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    for j in joins {
        let lat = j
            .join()
            .map_err(|_| anyhow!("serve load client panicked"))??;
        all.extend(lat);
    }
    Ok((all, wall.elapsed_s()))
}

/// Outcome tallies of one HTTP closed-loop run.
#[derive(Debug, Clone, Copy, Default)]
pub struct HttpLoadStats {
    /// 200s — answered with logits
    pub ok: u64,
    /// 429s — rejected at admission or shed in-queue past the deadline
    pub rejected: u64,
    /// any other status (4xx/5xx)
    pub failed: u64,
}

impl HttpLoadStats {
    /// Fraction of requests turned away for deadline reasons.
    pub fn shed_rate(&self) -> f64 {
        let total = self.ok + self.rejected + self.failed;
        if total == 0 {
            0.0
        } else {
            self.rejected as f64 / total as f64
        }
    }
}

/// The [`closed_loop`] harness over the network: `clients` keep-alive
/// HTTP connections drive `total` predict requests against a running
/// [`crate::serve::HttpFront`] at `addr`, round-robin over `model_ids`
/// (named via `names[id]`, sampling `pools[id]`). Request bodies are
/// pre-serialized so the measured path is socket + front + serve stack,
/// not client-side JSON formatting. Latencies are recorded for 200s
/// only; 429s and other failures are tallied in [`HttpLoadStats`].
pub fn closed_loop_http(addr: &str, names: &[String], model_ids: &[usize],
                        pools: &SamplePools, total: usize, clients: usize,
                        deadline_ms: Option<f64>)
                        -> Result<(Vec<(usize, f32)>, f64, HttpLoadStats)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0, HttpLoadStats::default()));
    }
    // one request body per (model, pool sample), serialized once
    let bodies: Arc<Vec<Vec<String>>> = Arc::new(
        pools
            .iter()
            .map(|pool| {
                pool.iter()
                    .map(|s| {
                        format!("{{\"input\":{}}}", Json::from_f32s(s))
                    })
                    .collect()
            })
            .collect(),
    );
    let names: Arc<Vec<String>> = Arc::new(names.to_vec());
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let addr = addr.to_string();
        let next = Arc::clone(&next);
        let bodies = Arc::clone(&bodies);
        let names = Arc::clone(&names);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<(Vec<(usize, f32)>, HttpLoadStats)> {
                let mut client = HttpClient::connect(&addr)?;
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % bodies[m].len();
                    let t = Timer::start();
                    let (status, body) = client.predict(
                        &names[m], &bodies[m][s], deadline_ms)?;
                    match status {
                        200 => {
                            stats.ok += 1;
                            lat.push((m, t.elapsed_ms() as f32));
                        }
                        429 => stats.rejected += 1,
                        _ => stats.failed += 1,
                    }
                    std::hint::black_box(body.len());
                }
                Ok((lat, stats))
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    let mut agg = HttpLoadStats::default();
    for j in joins {
        let (lat, stats) = j
            .join()
            .map_err(|_| anyhow!("serve http load client panicked"))??;
        all.extend(lat);
        agg.ok += stats.ok;
        agg.rejected += stats.rejected;
        agg.failed += stats.failed;
    }
    Ok((all, wall.elapsed_s(), agg))
}

/// The [`closed_loop`] harness over the binary wire protocol:
/// `clients` keep-alive [`WireClient`] connections drive `total`
/// predict requests against a running [`crate::serve::WireServer`] at
/// `addr`, round-robin over `model_ids` (named via `names[id]`,
/// sampling `pools[id]`). Whole predict frames are pre-encoded so the
/// measured path is socket + framing + serve stack with zero
/// per-request encoding — the binary analog of [`closed_loop_http`]'s
/// pre-serialized bodies, and the comparison that quantifies the JSON
/// tax. Outcomes tally into the same [`HttpLoadStats`] buckets so
/// shed-rate rows compare across transports.
pub fn closed_loop_wire(addr: &str, names: &[String], model_ids: &[usize],
                        pools: &SamplePools, total: usize, clients: usize,
                        deadline_ms: Option<f64>)
                        -> Result<(Vec<(usize, f32)>, f64, HttpLoadStats)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0, HttpLoadStats::default()));
    }
    // one complete predict frame per (model, pool sample), encoded once
    let frames: Arc<Vec<Vec<Vec<u8>>>> = Arc::new(
        pools
            .iter()
            .enumerate()
            .map(|(m, pool)| {
                pool.iter()
                    .map(|s| {
                        predict_frame_bytes(
                            &names[m],
                            &[s.as_slice()],
                            deadline_ms,
                        )
                        .map_err(|e| {
                            anyhow!("encode predict frame: {e}")
                        })
                    })
                    .collect::<Result<Vec<Vec<u8>>>>()
            })
            .collect::<Result<Vec<Vec<Vec<u8>>>>>()?,
    );
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let addr = addr.to_string();
        let next = Arc::clone(&next);
        let frames = Arc::clone(&frames);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> Result<(Vec<(usize, f32)>, HttpLoadStats)> {
                let mut client = WireClient::connect(&addr)?;
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % frames[m].len();
                    let t = Timer::start();
                    match client.request_frame(&frames[m][s])? {
                        WireReply::Outputs(rows) => {
                            stats.ok += 1;
                            lat.push((m, t.elapsed_ms() as f32));
                            std::hint::black_box(rows.len());
                        }
                        WireReply::Refused(e) if e.status == 429 => {
                            stats.rejected += 1;
                        }
                        WireReply::Refused(_) => stats.failed += 1,
                    }
                }
                Ok((lat, stats))
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    let mut agg = HttpLoadStats::default();
    for j in joins {
        let (lat, stats) = j
            .join()
            .map_err(|_| anyhow!("serve wire load client panicked"))??;
        all.extend(lat);
        agg.ok += stats.ok;
        agg.rejected += stats.rejected;
        agg.failed += stats.failed;
    }
    Ok((all, wall.elapsed_s(), agg))
}

/// The [`closed_loop`] harness through the cluster router: `clients`
/// threads drive `total` single-sample requests via
/// [`Router::predict_one`], round-robin over `model_ids` (named via
/// `names[id]`, sampling `pools[id]`). Latencies are recorded for
/// completed requests; deadline-shaped refusals and failures are
/// tallied in [`HttpLoadStats`] (same buckets as the HTTP loop, so
/// shed-rate rows compare across transports).
pub fn closed_loop_cluster(router: &Arc<Router>, names: &[String],
                           model_ids: &[usize], pools: &SamplePools,
                           total: usize, clients: usize,
                           deadline: Option<Duration>)
                           -> Result<(Vec<(usize, f32)>, f64,
                                      HttpLoadStats)> {
    let ids: Arc<Vec<usize>> = Arc::new(model_ids.to_vec());
    if ids.is_empty() {
        return Ok((Vec::new(), 0.0, HttpLoadStats::default()));
    }
    let names: Arc<Vec<String>> = Arc::new(names.to_vec());
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Timer::start();
    let mut joins = Vec::with_capacity(clients.max(1));
    for _ in 0..clients.max(1) {
        let rt = Arc::clone(router);
        let next = Arc::clone(&next);
        let pools = Arc::clone(pools);
        let names = Arc::clone(&names);
        let ids = Arc::clone(&ids);
        joins.push(std::thread::spawn(
            move || -> (Vec<(usize, f32)>, HttpLoadStats) {
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= total {
                        break;
                    }
                    let m = ids[r % ids.len()];
                    let s = (r / ids.len()) % pools[m].len();
                    let d = deadline.map(|d| Instant::now() + d);
                    let t = Timer::start();
                    match rt.predict_one(&names[m], &pools[m][s], d) {
                        Ok(out) => {
                            stats.ok += 1;
                            lat.push((m, t.elapsed_ms() as f32));
                            std::hint::black_box(out.len());
                        }
                        Err(RouteError::Rejected(_))
                        | Err(RouteError::Deadline(_)) => {
                            stats.rejected += 1;
                        }
                        Err(_) => stats.failed += 1,
                    }
                }
                (lat, stats)
            },
        ));
    }
    let mut all = Vec::with_capacity(total);
    let mut agg = HttpLoadStats::default();
    for j in joins {
        let (lat, stats) = j
            .join()
            .map_err(|_| anyhow!("cluster load client panicked"))?;
        all.extend(lat);
        agg.ok += stats.ok;
        agg.rejected += stats.rejected;
        agg.failed += stats.failed;
    }
    Ok((all, wall.elapsed_s(), agg))
}

/// An open-loop arrival schedule: where every request's send time comes
/// from. All schedules are deterministic given a seed, so bench rows and
/// fault-injection tests replay exactly.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Poisson process at `rps` requests/sec: i.i.d. exponential
    /// inter-arrival gaps, the standard memoryless open-loop model.
    Poisson { rps: f64 },
    /// Square-wave modulated rate: alternating phases of `burst`
    /// requests at `rps * factor` (hot) and `burst` requests at
    /// `rps / factor` (cold). Deterministic gaps within a phase; the
    /// seed is accepted for interface uniformity but unused.
    Bursty { rps: f64, burst: usize, factor: f64 },
    /// Replay recorded inter-arrival gaps in ms, cycling when the trace
    /// is shorter than the run (offsets keep accumulating across
    /// cycles, so the replayed load repeats its shape end to end).
    Trace(Vec<f64>),
}

impl Arrival {
    /// Parse a CLI `--arrival` kind with its rate knobs. `kind` is
    /// `poisson` or `bursty`; traces come from [`Arrival::from_trace_file`].
    pub fn parse(kind: &str, rps: f64, burst: usize,
                 factor: f64) -> Result<Arrival> {
        ensure!(rps.is_finite() && rps > 0.0,
                "open-loop rate must be > 0 req/s (got {rps})");
        match kind {
            "poisson" => Ok(Arrival::Poisson { rps }),
            "bursty" => {
                ensure!(burst > 0,
                        "bursty arrival needs --burst > 0 (got {burst})");
                ensure!(factor.is_finite() && factor >= 1.0,
                        "bursty factor must be >= 1.0 (got {factor})");
                Ok(Arrival::Bursty { rps, burst, factor })
            }
            other => bail!(
                "unknown arrival kind `{other}` (expected poisson|bursty)"
            ),
        }
    }

    /// Load a recorded trace: one inter-arrival gap in ms per line,
    /// blank lines and `#` comments skipped.
    pub fn from_trace_file(path: &str) -> Result<Arrival> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read trace `{path}`: {e}"))?;
        let mut gaps = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let gap: f64 = line.parse().map_err(|_| {
                anyhow!("trace `{path}` line {}: `{line}` is not a \
                         number of ms", i + 1)
            })?;
            ensure!(gap.is_finite() && gap >= 0.0,
                    "trace `{path}` line {}: gap must be >= 0 ms", i + 1);
            gaps.push(gap);
        }
        ensure!(!gaps.is_empty(), "trace `{path}` holds no gaps");
        Ok(Arrival::Trace(gaps))
    }

    /// Short tag for bench-row labels (`poisson` / `bursty` / `trace`).
    pub fn tag(&self) -> &'static str {
        match self {
            Arrival::Poisson { .. } => "poisson",
            Arrival::Bursty { .. } => "bursty",
            Arrival::Trace(_) => "trace",
        }
    }

    /// The schedule itself: `n` monotone non-decreasing send offsets in
    /// ms from run start. Same `(arrival, n, seed)` -> same offsets.
    pub fn offsets_ms(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        match self {
            Arrival::Poisson { rps } => {
                let mut rng = Rng::new(seed);
                for _ in 0..n {
                    let u = rng.f32() as f64; // [0, 1)
                    t += -(1.0 - u).ln() / rps * 1e3;
                    out.push(t);
                }
            }
            Arrival::Bursty { rps, burst, factor } => {
                let burst = (*burst).max(1);
                let hot_gap = 1e3 / (rps * factor);
                let cold_gap = 1e3 * factor / rps;
                for i in 0..n {
                    let phase = (i / burst) % 2;
                    t += if phase == 0 { hot_gap } else { cold_gap };
                    out.push(t);
                }
            }
            Arrival::Trace(gaps) => {
                for i in 0..n {
                    t += gaps[i % gaps.len()];
                    out.push(t);
                }
            }
        }
        out
    }
}

/// What one open-loop request came back as (same buckets as
/// [`HttpLoadStats`], decided by the transport-specific submit closure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOutcome {
    /// answered with logits
    Done,
    /// turned away for deadline reasons (429-shaped)
    Rejected,
    /// any other failure
    Failed,
}

/// Everything one open-loop run measured. `lat_ms` holds
/// scheduled-arrival-to-completion latencies for [`LoadOutcome::Done`]
/// requests only; rejected/failed requests carry no latency but still
/// count against SLO attainment.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub lat_ms: Vec<f32>,
    pub stats: HttpLoadStats,
    pub wall_s: f64,
    /// requests/sec the schedule offered (n / schedule span)
    pub offered_rps: f64,
    /// requests/sec actually answered OK (ok / wall clock)
    pub achieved_rps: f64,
    /// all issued requests (= ok + rejected + failed)
    pub total: usize,
}

impl OpenLoopReport {
    /// Latency-under-SLO curve: for each deadline bound in ms, the
    /// fraction of *all issued* requests answered OK within the bound.
    /// Rejected and failed requests count against attainment — a server
    /// that sheds 30% of load cannot report 100% SLO attainment no
    /// matter how fast the survivors were.
    pub fn slo_curve(&self, bounds_ms: &[f32]) -> Vec<(f32, f64)> {
        bounds_ms
            .iter()
            .map(|&b| {
                let met = self
                    .lat_ms
                    .iter()
                    .filter(|&&ms| ms <= b)
                    .count();
                (b, met as f64 / self.total.max(1) as f64)
            })
            .collect()
    }
}

/// Generic open-loop driver: fire one request per entry of
/// `offsets_ms` (a schedule from [`Arrival::offsets_ms`]) at its
/// scheduled time, round-robin over `model_ids` sampling `pools`.
/// `workers` threads share the schedule; a request whose turn comes up
/// late (all workers busy — the server is backed up) fires immediately
/// and its lateness counts into its latency, which is the whole point
/// of an open loop. `submit` maps `(model_id, sample)` to a
/// [`LoadOutcome`]; transport wrappers below supply it.
pub fn open_loop<F>(offsets_ms: &[f64], model_ids: &[usize],
                    pools: &SamplePools, workers: usize,
                    submit: F) -> Result<OpenLoopReport>
where
    F: Fn(usize, &[f32]) -> LoadOutcome + Sync,
{
    let n = offsets_ms.len();
    if n == 0 || model_ids.is_empty() {
        return Ok(OpenLoopReport {
            lat_ms: Vec::new(),
            stats: HttpLoadStats::default(),
            wall_s: 0.0,
            offered_rps: 0.0,
            achieved_rps: 0.0,
            total: 0,
        });
    }
    let next = AtomicUsize::new(0);
    let merged: Mutex<(Vec<f32>, HttpLoadStats)> =
        Mutex::new((Vec::with_capacity(n), HttpLoadStats::default()));
    let wall = Timer::start();
    let start = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            scope.spawn(|| {
                let mut lat = Vec::new();
                let mut stats = HttpLoadStats::default();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= n {
                        break;
                    }
                    let sched = start
                        + Duration::from_secs_f64(offsets_ms[r] / 1e3);
                    let now = Instant::now();
                    if sched > now {
                        std::thread::sleep(sched - now);
                    }
                    let m = model_ids[r % model_ids.len()];
                    let s = (r / model_ids.len()) % pools[m].len();
                    let outcome = submit(m, &pools[m][s]);
                    // latency from the *scheduled* send, so queueing
                    // behind a slow server is charged to the request
                    let ms = Instant::now()
                        .saturating_duration_since(sched)
                        .as_secs_f64()
                        * 1e3;
                    match outcome {
                        LoadOutcome::Done => {
                            stats.ok += 1;
                            lat.push(ms as f32);
                        }
                        LoadOutcome::Rejected => stats.rejected += 1,
                        LoadOutcome::Failed => stats.failed += 1,
                    }
                }
                let mut g = merged.lock().unwrap();
                g.0.extend(lat);
                g.1.ok += stats.ok;
                g.1.rejected += stats.rejected;
                g.1.failed += stats.failed;
            });
        }
    });
    let wall_s = wall.elapsed_s();
    let (lat_ms, stats) = merged.into_inner().unwrap();
    let span_s = (offsets_ms[n - 1] / 1e3).max(1e-9);
    Ok(OpenLoopReport {
        achieved_rps: stats.ok as f64 / wall_s.max(1e-9),
        offered_rps: n as f64 / span_s,
        total: n,
        lat_ms,
        stats,
        wall_s,
    })
}

/// [`open_loop`] against an in-process [`Server`]: submissions go
/// through `try_submit` (admission gate included) with a per-request
/// deadline of `deadline` from the *actual* send time; 429-shaped
/// refusals ([`SubmitError::Rejected`] / [`SubmitError::QueueDeadline`]
/// / [`ReplyError::DeadlineExceeded`]) tally as rejected.
pub fn open_loop_server(server: &Arc<Server>, names: &[String],
                        model_ids: &[usize], pools: &SamplePools,
                        offsets_ms: &[f64], workers: usize,
                        deadline: Option<Duration>)
                        -> Result<OpenLoopReport> {
    use super::server::SubmitError;
    open_loop(offsets_ms, model_ids, pools, workers, |m, sample| {
        let d = deadline.map(|d| Instant::now() + d);
        match server.try_submit(&names[m], sample, d) {
            Ok(ticket) => match ticket.wait_reply(None) {
                Ok(out) => {
                    std::hint::black_box(out.len());
                    LoadOutcome::Done
                }
                Err(ReplyError::DeadlineExceeded(_)) => {
                    LoadOutcome::Rejected
                }
                Err(ReplyError::Failed(_)) => LoadOutcome::Failed,
            },
            Err(SubmitError::Rejected(_))
            | Err(SubmitError::QueueDeadline(_)) => LoadOutcome::Rejected,
            Err(_) => LoadOutcome::Failed,
        }
    })
}

/// [`open_loop`] through the cluster router: requests go through
/// [`Router::predict_one`], so hedging, circuit breakers, and failover
/// are all in the measured path. Deadline-shaped refusals tally as
/// rejected, everything else as failed — the same buckets as
/// [`closed_loop_cluster`].
pub fn open_loop_cluster(router: &Arc<Router>, names: &[String],
                         model_ids: &[usize], pools: &SamplePools,
                         offsets_ms: &[f64], workers: usize,
                         deadline: Option<Duration>)
                         -> Result<OpenLoopReport> {
    open_loop(offsets_ms, model_ids, pools, workers, |m, sample| {
        let d = deadline.map(|d| Instant::now() + d);
        match router.predict_one(&names[m], sample, d) {
            Ok(out) => {
                std::hint::black_box(out.len());
                LoadOutcome::Done
            }
            Err(RouteError::Rejected(_)) | Err(RouteError::Deadline(_)) => {
                LoadOutcome::Rejected
            }
            Err(_) => LoadOutcome::Failed,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{ExecMode, Plan, PlanOptions};
    use crate::serve::{Registry, ServerConfig};
    use crate::testkit::models::synth_mlp_model;
    use crate::util::Rng;
    use std::time::Duration;

    #[test]
    fn closed_loop_answers_every_request() {
        let (graph, model) = synth_mlp_model(4);
        let plan = Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.register("mlp", plan).unwrap();
        let server = Arc::new(
            Server::start(reg, ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
                ..Default::default()
            })
            .unwrap(),
        );
        let mut rng = Rng::new(4);
        let pools: SamplePools =
            Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
        let (lat, secs) =
            closed_loop(&server, &[0], &pools, 17, 3).unwrap();
        assert_eq!(lat.len(), 17);
        assert!(lat.iter().all(|(m, ms)| *m == 0 && *ms >= 0.0));
        assert!(secs > 0.0);
        let server = Arc::try_unwrap(server).ok().expect("clients done");
        assert_eq!(server.shutdown()[0].requests, 17);
    }

    #[test]
    fn poisson_offsets_are_seeded_monotone_and_rate_matched() {
        let a = Arrival::parse("poisson", 1000.0, 0, 0.0).unwrap();
        let x = a.offsets_ms(2000, 7);
        let y = a.offsets_ms(2000, 7);
        assert_eq!(x, y, "same seed must replay the same schedule");
        let z = a.offsets_ms(2000, 8);
        assert_ne!(x, z, "different seed must vary the schedule");
        assert!(x.windows(2).all(|w| w[1] >= w[0]));
        // mean gap of a 1000 rps Poisson process is 1 ms
        let mean_gap = x.last().unwrap() / x.len() as f64;
        assert!((mean_gap - 1.0).abs() < 0.15, "{mean_gap}");
    }

    #[test]
    fn bursty_offsets_alternate_hot_and_cold_phases() {
        let a = Arrival::parse("bursty", 100.0, 3, 4.0).unwrap();
        let x = a.offsets_ms(12, 1);
        assert!(x.windows(2).all(|w| w[1] > w[0]));
        // hot gap 2.5 ms for 3 requests, then cold gap 40 ms for 3
        assert!((x[0] - 2.5).abs() < 1e-9, "{}", x[0]);
        assert!((x[3] - x[2] - 40.0).abs() < 1e-9);
        assert!((x[6] - x[5] - 2.5).abs() < 1e-9);
    }

    #[test]
    fn trace_offsets_cycle_and_accumulate() {
        let a = Arrival::Trace(vec![1.0, 2.0]);
        assert_eq!(a.offsets_ms(5, 0), vec![1.0, 3.0, 4.0, 6.0, 7.0]);
        assert_eq!(a.tag(), "trace");
    }

    #[test]
    fn trace_file_parses_gaps_and_rejects_junk() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("lutq_trace_{}.txt", std::process::id()));
        std::fs::write(&p, "# recorded gaps\n1.5\n\n2.5\n").unwrap();
        let a = Arrival::from_trace_file(p.to_str().unwrap()).unwrap();
        assert_eq!(a.offsets_ms(3, 0), vec![1.5, 4.0, 5.5]);
        std::fs::write(&p, "1.5\nnope\n").unwrap();
        assert!(Arrival::from_trace_file(p.to_str().unwrap()).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn arrival_parse_rejects_nonsense() {
        assert!(Arrival::parse("poisson", 0.0, 0, 0.0).is_err());
        assert!(Arrival::parse("poisson", f64::NAN, 0, 0.0).is_err());
        assert!(Arrival::parse("bursty", 100.0, 0, 2.0).is_err());
        assert!(Arrival::parse("bursty", 100.0, 8, 0.5).is_err());
        assert!(Arrival::parse("uniform", 100.0, 0, 0.0).is_err());
    }

    #[test]
    fn slo_curve_counts_rejections_against_attainment() {
        let rep = OpenLoopReport {
            lat_ms: vec![1.0, 2.0, 3.0, 10.0],
            stats: HttpLoadStats { ok: 4, rejected: 3, failed: 1 },
            wall_s: 1.0,
            offered_rps: 8.0,
            achieved_rps: 4.0,
            total: 8,
        };
        let curve = rep.slo_curve(&[0.5, 2.0, 5.0, 20.0]);
        assert_eq!(curve[0], (0.5, 0.0));
        assert_eq!(curve[1], (2.0, 2.0 / 8.0));
        assert_eq!(curve[2], (5.0, 3.0 / 8.0));
        // even an infinite budget cannot reach 1.0: half the load was
        // turned away or failed
        assert_eq!(curve[3], (20.0, 4.0 / 8.0));
        assert!(curve.windows(2).all(|w| w[1].1 >= w[0].1));
    }

    #[test]
    fn open_loop_server_answers_every_scheduled_request() {
        let (graph, model) = synth_mlp_model(4);
        let plan = Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap();
        let mut reg = Registry::new();
        reg.register("mlp", plan).unwrap();
        let server = Arc::new(
            Server::start(reg, ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
                ..Default::default()
            })
            .unwrap(),
        );
        let mut rng = Rng::new(9);
        let pools: SamplePools =
            Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
        let arrival = Arrival::Poisson { rps: 2000.0 };
        let offsets = arrival.offsets_ms(40, 11);
        let rep = open_loop_server(&server, &["mlp".into()], &[0],
                                   &pools, &offsets, 8, None)
            .unwrap();
        assert_eq!(rep.total, 40);
        assert_eq!(rep.stats.ok, 40);
        assert_eq!(rep.stats.rejected + rep.stats.failed, 0);
        assert_eq!(rep.lat_ms.len(), 40);
        assert!(rep.offered_rps > 0.0 && rep.achieved_rps > 0.0);
        // full attainment at an absurdly generous bound
        let curve = rep.slo_curve(&[60_000.0]);
        assert_eq!(curve[0].1, 1.0);
        let server = Arc::try_unwrap(server).ok().expect("clients done");
        assert_eq!(server.shutdown()[0].requests, 40);
    }
}
