//! Dependency-free HTTP/1.1 front over [`Server`]: the network face of
//! the serve stack (std `TcpListener` + the in-tree [`crate::jsonic`]
//! JSON — no external crates).
//!
//! Endpoints:
//!
//! | method + path                    | reply                          |
//! |----------------------------------|--------------------------------|
//! | `POST /v1/models/{name}:predict` | `{"model","output":[...]}`     |
//! | `GET /v1/models`                 | `{"models":[{name,version,..}]}` |
//! | `GET /healthz`                   | `{"status":"ok","models":N}`   |
//! | `GET /metrics`                   | per-model [`ModelReport`] rows |
//! | `POST /v1/models/{name}:load`    | admin: hot-load a version      |
//! | `POST /v1/models/{name}:unload`  | admin: drop a version          |
//! | `POST /v1/models/{name}:setDefault` | admin: blue-green cutover   |
//!
//! `{name}` everywhere may be version-qualified (`name@version`);
//! unqualified predicts go to the model's current default version. The
//! admin endpoints take the version from the path qualifier or a
//! `version` body field, and `:load` treats the rest of the body as the
//! load spec handed to the server's [`PlanLoader`]
//! ([`Server::set_loader`]). Lifecycle failures are typed: 404 unknown
//! name/version, 409 conflicts (duplicate load, unloading the default),
//! 501 when the backend has no admin support or loader.
//!
//! A predict request may carry a client deadline as the
//! [`DEADLINE_HEADER`] header (milliseconds, fractional ok) or a
//! `deadline_ms` JSON field (the header wins). The deadline clock starts
//! when the request is fully read; the admission gate rejects requests
//! that provably cannot meet it (429 before a queue slot is consumed),
//! and admitted requests that overstay their deadline in the queue are
//! shed by the batcher — also a 429. Error codes: 400 malformed body /
//! wrong input length, 404 unknown model or path, 405 wrong method,
//! 413/431 oversized body/headers, 429 `deadline_exceeded`, 500
//! execution failure, 501 chunked bodies, 503 shutting down or at the
//! connection cap.
//!
//! Concurrency model: one accept thread; one thread per live connection
//! (keep-alive), bounded by [`HttpConfig::max_conns`] — past the cap new
//! connections get an immediate 503 instead of queueing invisibly.
//! Handler threads only parse/route; all batching, admission and
//! execution stay behind the [`ServeBackend`] seam — a [`Server`]
//! worker pool for `lutq serve`, a sharding
//! [`Router`](super::cluster::Router) for `lutq route`.
//!
//! [`ModelReport`]: super::ModelReport
//!
//! The file also ships the matching minimal client ([`HttpClient`]) so
//! `serve-bench --transport http` and the smoke tests measure the full
//! network path with the same keep-alive framing the front speaks.
//!
//! For hot paths where JSON encode/parse dominates small-model
//! inference, the binary framed front in [`super::wire`] serves the
//! same [`ServeBackend`] with raw little-endian tensor bodies; this
//! HTTP front stays up next to it for curl, debugging and interop.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::jsonic::{self, Json};

use super::batcher::ReplyError;
use super::registry::{split_versioned, LifecycleError, ModelInfo};
use super::server::{Server, SubmitError};

/// Request header carrying the client deadline in (fractional) ms.
pub const DEADLINE_HEADER: &str = "x-lutq-deadline-ms";

const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;
/// Client deadlines are clamped to one day: far beyond any useful
/// serving deadline, and safely inside `Duration`/`Instant` range.
/// Shared with the wire front so both transports clamp identically.
pub(crate) const MAX_DEADLINE_MS: f64 = 86_400_000.0;

/// Typed predict failure every HTTP-servable backend maps onto; the
/// front turns each variant into its status code + JSON error body.
#[derive(Debug)]
pub enum PredictError {
    /// 404 `unknown_model`
    UnknownModel(String),
    /// 400 `bad_input`
    BadInput(String),
    /// 429 `deadline_exceeded` (admission rejection or in-queue shed)
    Deadline(String),
    /// 503, with the error-body code to use (`shutting_down` for a
    /// draining [`Server`], `no_healthy_replicas` for a cluster router
    /// with every backend down)
    Unavailable(&'static str, String),
    /// 500 `exec_failed`
    Failed(String),
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredictError::UnknownModel(m)
            | PredictError::BadInput(m)
            | PredictError::Deadline(m)
            | PredictError::Failed(m) => write!(f, "{m}"),
            PredictError::Unavailable(code, m) => {
                write!(f, "{code}: {m}")
            }
        }
    }
}

impl std::error::Error for PredictError {}

/// One model-lifecycle administration request, shared by the HTTP
/// admin endpoints and the wire protocol's `Admin` frame.
#[derive(Debug, Clone)]
pub enum AdminAction {
    /// hot-load `name@version`; `spec` is handed to the backend's
    /// [`PlanLoader`](super::PlanLoader)
    Load { name: String, version: String, spec: Json },
    /// drop `name@version` (the default version is refused)
    Unload { name: String, version: String },
    /// make `name@version` answer unversioned requests (blue-green)
    SetDefault { name: String, version: String },
}

/// Typed admin failure; the fronts map each variant to its status code.
#[derive(Debug)]
pub enum AdminError {
    /// 404: unknown model name or version
    NotFound(String),
    /// 409: duplicate load, or unloading the default version
    Conflict(String),
    /// 400: malformed name/version/spec
    Invalid(String),
    /// 501: backend has no admin support, or no loader installed
    Unsupported(String),
    /// 500: the loader failed to compile the spec
    Failed(String),
}

impl std::fmt::Display for AdminError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdminError::NotFound(m)
            | AdminError::Conflict(m)
            | AdminError::Invalid(m)
            | AdminError::Unsupported(m)
            | AdminError::Failed(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for AdminError {}

fn lifecycle_to_admin(e: LifecycleError) -> AdminError {
    match e {
        LifecycleError::UnknownModel(m)
        | LifecycleError::UnknownVersion(m) => AdminError::NotFound(m),
        LifecycleError::DefaultInUse(m)
        | LifecycleError::Duplicate(m) => AdminError::Conflict(m),
        LifecycleError::Invalid(m) => AdminError::Invalid(m),
    }
}

/// What the HTTP front needs from a serving backend. Implemented by
/// [`Server`] (one process) and by
/// [`Router`](super::cluster::Router) (sharding across replicas), so
/// `lutq serve` and `lutq route` run the same front, API and error
/// codes.
pub trait ServeBackend: Send + Sync {
    /// `GET /healthz` status + body.
    fn healthz(&self) -> (u16, Json);
    /// `GET /v1/models` rows.
    fn infos(&self) -> Vec<ModelInfo>;
    /// `GET /metrics` rows (already-built JSON objects).
    fn metric_rows(&self) -> Vec<Json>;
    /// One sample in, logits out (blocking until answered).
    fn predict(
        &self,
        model: &str,
        input: &[f32],
        deadline: Option<Instant>,
    ) -> std::result::Result<Vec<f32>, PredictError>;
    /// Model lifecycle administration (load / unload / set-default).
    /// Default: unsupported (501) — the cluster router, for example,
    /// administers replicas out of band, not through this seam.
    fn admin(&self, action: AdminAction)
             -> std::result::Result<Json, AdminError> {
        let _ = action;
        Err(AdminError::Unsupported(
            "this backend does not support model lifecycle \
             administration"
                .to_string(),
        ))
    }
}

impl ServeBackend for Server {
    fn healthz(&self) -> (u16, Json) {
        (
            200,
            Json::obj(vec![
                ("status", Json::str("ok")),
                // live base names, not slots: unloaded versions and
                // dead slots don't inflate the health summary
                ("models",
                 Json::num(self.registry().names().len() as f64)),
                ("workers", Json::num(self.worker_count() as f64)),
            ]),
        )
    }

    fn infos(&self) -> Vec<ModelInfo> {
        self.registry().infos()
    }

    fn metric_rows(&self) -> Vec<Json> {
        self.reports().iter().map(|r| r.to_json()).collect()
    }

    fn predict(
        &self,
        model: &str,
        input: &[f32],
        deadline: Option<Instant>,
    ) -> std::result::Result<Vec<f32>, PredictError> {
        let ticket = self
            .try_submit(model, input, deadline)
            .map_err(|e| match e {
                SubmitError::UnknownModel(m) => {
                    PredictError::UnknownModel(m)
                }
                SubmitError::BadInput(m) => PredictError::BadInput(m),
                e @ SubmitError::Rejected(_) => {
                    PredictError::Deadline(e.to_string())
                }
                SubmitError::QueueDeadline(m) => {
                    PredictError::Deadline(m)
                }
                SubmitError::Closed(m) => {
                    PredictError::Unavailable("shutting_down", m)
                }
            })?;
        match ticket.wait_reply(None) {
            Ok(out) => Ok(out),
            Err(ReplyError::DeadlineExceeded(m)) => {
                Err(PredictError::Deadline(m))
            }
            Err(ReplyError::Failed(m)) => Err(PredictError::Failed(m)),
        }
    }

    fn admin(&self, action: AdminAction)
             -> std::result::Result<Json, AdminError> {
        let ok = |name: &str, version: &str, slot: usize| {
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::str(name)),
                ("version", Json::str(version)),
                ("slot", Json::num(slot as f64)),
            ])
        };
        match action {
            AdminAction::Load { name, version, spec } => {
                let plan =
                    self.compile_spec(&spec).map_err(|e| match e {
                        None => AdminError::Unsupported(
                            "no plan loader installed on this server; \
                             hot load requires `lutq serve` (which \
                             compiles manifest/synthetic specs) or an \
                             embedded Server::set_loader"
                                .to_string(),
                        ),
                        Some(msg) => AdminError::Failed(msg),
                    })?;
                let slot = self
                    .load_version(&name, &version, plan)
                    .map_err(lifecycle_to_admin)?;
                Ok(ok(&name, &version, slot))
            }
            AdminAction::Unload { name, version } => {
                let slot = self
                    .unload_version(&name, &version)
                    .map_err(lifecycle_to_admin)?;
                Ok(ok(&name, &version, slot))
            }
            AdminAction::SetDefault { name, version } => {
                self.set_default_version(&name, &version)
                    .map_err(lifecycle_to_admin)?;
                let slot = self
                    .registry()
                    .id(&format!("{name}@{version}"))
                    .unwrap_or(0);
                Ok(ok(&name, &version, slot))
            }
        }
    }
}

/// Network-front knobs.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// bind address; port 0 picks an ephemeral port (see
    /// [`HttpFront::addr`])
    pub addr: String,
    /// max concurrent connections (each owns one handler thread);
    /// excess connections are answered 503 immediately
    pub max_conns: usize,
    /// per-connection socket read/write timeout
    pub io_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:8080".to_string(),
            max_conns: 256,
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// A running HTTP front. Dropping (or [`shutdown`](HttpFront::shutdown))
/// stops the accept loop and joins every connection handler; the
/// underlying [`Server`] keeps running and is shut down separately.
pub struct HttpFront {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpFront {
    /// Bind `cfg.addr` and start serving `server` over HTTP. Any
    /// [`ServeBackend`] works: an `Arc<Server>` (single process) or an
    /// `Arc<Router>` (cluster routing tier).
    pub fn start<B>(server: Arc<B>, cfg: HttpConfig) -> Result<HttpFront>
    where
        B: ServeBackend + 'static,
    {
        let backend: Arc<dyn ServeBackend> = server;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("serve: bind http on {}", cfg.addr))?;
        let addr = listener.local_addr().context("serve: local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("lutq-http-accept".to_string())
                .spawn(move || {
                    accept_loop(&listener, &stop, &backend, &conns, &cfg)
                })
                .context("serve: spawn http accept thread")?
        };
        Ok(HttpFront { addr, stop, accept: Some(accept), conns })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, then join every connection handler. Blocks until
    /// live keep-alive connections close or hit the io timeout.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // the accept thread is blocked in accept(); poke it awake
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpFront {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_and_join();
        }
    }
}

fn accept_loop(listener: &TcpListener, stop: &AtomicBool,
               server: &Arc<dyn ServeBackend>,
               conns: &Mutex<Vec<JoinHandle<()>>>, cfg: &HttpConfig) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                // don't hot-spin on persistent accept errors (e.g. fd
                // exhaustion) — give handlers a chance to free fds
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(cfg.io_timeout));
        let _ = stream.set_write_timeout(Some(cfg.io_timeout));
        let mut guard = conns.lock().unwrap();
        // reap finished handlers so the vec tracks *live* connections
        guard.retain(|h| !h.is_finished());
        if guard.len() >= cfg.max_conns.max(1) {
            drop(guard);
            let mut stream = stream;
            let _ = write_response(
                &mut stream,
                503,
                &err_body("overloaded",
                          "connection cap reached; retry later"),
                false,
            );
            continue;
        }
        let srv = Arc::clone(server);
        let spawned = std::thread::Builder::new()
            .name("lutq-http-conn".to_string())
            .spawn(move || handle_connection(stream, &srv));
        match spawned {
            Ok(h) => guard.push(h),
            Err(_) => { /* out of threads: drop the connection */ }
        }
    }
}

// ---------------------------------------------------------------- server

struct HttpRequest {
    method: String,
    path: String,
    /// header names lowercased
    headers: Vec<(String, String)>,
    body: Vec<u8>,
    /// when the request was fully read — the deadline clock's zero
    arrived: Instant,
}

impl HttpRequest {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

enum Inbound {
    Req(HttpRequest),
    /// clean end of the connection (or an unrecoverable io error)
    Eof,
    /// protocol violation: answer with this status, then close
    Bad(u16, String),
}

/// `read_line` with a hard cap on consumed bytes: a single endless line
/// (no `\n`) can otherwise buffer unbounded memory before any length
/// check runs. At most `cap` bytes are read; a line that hits the cap
/// without terminating is the caller's cue to answer 431 and close.
fn read_line_capped(r: &mut BufReader<TcpStream>, cap: usize,
                    line: &mut String) -> std::io::Result<usize> {
    r.by_ref().take(cap as u64).read_line(line)
}

fn read_request(r: &mut BufReader<TcpStream>) -> Inbound {
    let mut line = String::new();
    match read_line_capped(r, MAX_HEADER_BYTES, &mut line) {
        Ok(0) | Err(_) => return Inbound::Eof,
        Ok(_) => {
            if !line.ends_with('\n') && line.len() >= MAX_HEADER_BYTES {
                return Inbound::Bad(431, "request line too large".into());
            }
        }
    }
    let mut parts = line.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v)) => {
                (m.to_string(), t.to_string(), v.to_string())
            }
            _ => {
                return Inbound::Bad(
                    400,
                    format!("malformed request line `{}`", line.trim()),
                )
            }
        };
    if !version.starts_with("HTTP/1.") {
        return Inbound::Bad(505, format!("unsupported version {version}"));
    }
    let mut headers: Vec<(String, String)> = Vec::new();
    let mut header_bytes = line.len();
    loop {
        let mut h = String::new();
        match read_line_capped(r, MAX_HEADER_BYTES, &mut h) {
            Ok(0) | Err(_) => return Inbound::Eof,
            Ok(n) => {
                header_bytes += n;
                if !h.ends_with('\n') && h.len() >= MAX_HEADER_BYTES {
                    return Inbound::Bad(431,
                                        "header line too large".into());
                }
            }
        }
        if header_bytes > MAX_HEADER_BYTES {
            return Inbound::Bad(431, "header section too large".into());
        }
        let t = h.trim_end_matches(|c| c == '\r' || c == '\n');
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(),
                          v.trim().to_string()));
        }
    }
    let get = |name: &str| {
        headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    };
    if get("transfer-encoding").is_some() {
        return Inbound::Bad(501, "chunked bodies not supported".into());
    }
    let len = match get("content-length") {
        None => 0usize,
        Some(v) => match v.trim().parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                return Inbound::Bad(
                    400,
                    format!("bad content-length `{v}`"),
                )
            }
        },
    };
    if len > MAX_BODY_BYTES {
        return Inbound::Bad(413, format!("body of {len} bytes too large"));
    }
    let mut body = vec![0u8; len];
    if len > 0 && r.read_exact(&mut body).is_err() {
        return Inbound::Eof;
    }
    // strip any query string; routing is on the bare path
    let path = target.split('?').next().unwrap_or("").to_string();
    Inbound::Req(HttpRequest {
        method,
        path,
        headers,
        body,
        arrived: Instant::now(),
    })
}

fn handle_connection(stream: TcpStream,
                     server: &Arc<dyn ServeBackend>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    loop {
        match read_request(&mut reader) {
            Inbound::Eof => return,
            Inbound::Bad(status, msg) => {
                let _ = write_response(&mut stream, status,
                                       &err_body("bad_request", &msg),
                                       false);
                return;
            }
            Inbound::Req(req) => {
                let keep = req.keep_alive();
                let (status, body) = route(server, &req);
                if write_response(&mut stream, status, &body, keep)
                    .is_err()
                    || !keep
                {
                    return;
                }
            }
        }
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

fn write_response(w: &mut TcpStream, status: u16, body: &Json,
                  keep_alive: bool) -> std::io::Result<()> {
    let body = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

fn err_body(code: &str, msg: &str) -> Json {
    Json::obj(vec![
        ("error", Json::str(code)),
        ("message", Json::str(msg)),
    ])
}

/// The `GET /v1/models` body. Shared with the wire front's `Models`
/// frame so both transports publish the identical catalog JSON.
pub(crate) fn models_body(infos: &[ModelInfo]) -> Json {
    Json::obj(vec![(
        "models",
        Json::arr(
            infos
                .iter()
                .map(|i| {
                    Json::obj(vec![
                        ("name", Json::str(&i.name)),
                        ("version", Json::str(&i.version)),
                        ("default", Json::Bool(i.default)),
                        ("backend", Json::str(&i.backend)),
                        ("input", Json::from_usizes(&i.input)),
                        ("output", Json::from_usizes(&i.output)),
                        ("batch_invariant",
                         Json::Bool(i.batch_invariant)),
                    ])
                })
                .collect(),
        ),
    )])
}

fn route(server: &Arc<dyn ServeBackend>,
         req: &HttpRequest) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => server.healthz(),
        ("GET", "/metrics") => (200, Json::arr(server.metric_rows())),
        ("GET", "/v1/models") => (200, models_body(&server.infos())),
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/models") => (
            405,
            err_body("method_not_allowed",
                     &format!("{} {}", req.method, req.path)),
        ),
        (method, path) => {
            let Some(rest) = path.strip_prefix("/v1/models/") else {
                return (
                    404,
                    err_body("not_found",
                             &format!("no route for {path}")),
                );
            };
            if let Some(name) = rest.strip_suffix(":predict") {
                return if method == "POST" {
                    predict(server, name, req)
                } else {
                    (405,
                     err_body("method_not_allowed",
                              "predict requires POST"))
                };
            }
            for (suffix, verb) in [
                (":load", AdminVerb::Load),
                (":unload", AdminVerb::Unload),
                (":setDefault", AdminVerb::SetDefault),
            ] {
                if let Some(name) = rest.strip_suffix(suffix) {
                    return if method == "POST" {
                        admin_request(server, verb, name, req)
                    } else {
                        (405,
                         err_body("method_not_allowed",
                                  "admin endpoints require POST"))
                    };
                }
            }
            (404, err_body("not_found", &format!("no route for {path}")))
        }
    }
}

/// Which admin endpoint a request hit.
#[derive(Clone, Copy)]
pub(crate) enum AdminVerb {
    Load,
    Unload,
    SetDefault,
}

impl AdminVerb {
    pub(crate) fn from_str(s: &str) -> Option<AdminVerb> {
        match s {
            "load" => Some(AdminVerb::Load),
            "unload" => Some(AdminVerb::Unload),
            "setDefault" => Some(AdminVerb::SetDefault),
            _ => None,
        }
    }
}

/// Resolve the `(name, version)` an admin request targets: the path
/// qualifier (`name@version`) wins, a `version` body field is the
/// fallback. All three lifecycle verbs require an explicit version.
pub(crate) fn parse_admin_target(model_ref: &str, body: &Json)
                                 -> std::result::Result<(String, String),
                                                        String> {
    let (name, qualified) = split_versioned(model_ref);
    let version = match qualified {
        Some(v) if !v.is_empty() => v.to_string(),
        _ => match body.get("version").and_then(|j| j.as_str()) {
            Some(v) if !v.is_empty() => v.to_string(),
            _ => {
                return Err(
                    "model version required: address the model as \
                     `name@version` or carry a `version` field in the \
                     body"
                        .to_string(),
                )
            }
        },
    };
    Ok((name.to_string(), version))
}

/// Build the [`AdminAction`] for one verb + target + body. For `load`
/// the body doubles as the loader spec.
pub(crate) fn build_admin_action(verb: AdminVerb, model_ref: &str,
                                 body: Json)
                                 -> std::result::Result<AdminAction,
                                                        String> {
    let (name, version) = parse_admin_target(model_ref, &body)?;
    Ok(match verb {
        AdminVerb::Load => AdminAction::Load { name, version, spec: body },
        AdminVerb::Unload => AdminAction::Unload { name, version },
        AdminVerb::SetDefault => {
            AdminAction::SetDefault { name, version }
        }
    })
}

/// Map an admin outcome onto `(status, body)` — shared with the wire
/// front so both transports publish identical admin semantics.
pub(crate) fn admin_result_body(
    res: std::result::Result<Json, AdminError>) -> (u16, Json) {
    match res {
        Ok(j) => (200, j),
        Err(AdminError::NotFound(m)) => (404, err_body("not_found", &m)),
        Err(AdminError::Conflict(m)) => (409, err_body("conflict", &m)),
        Err(AdminError::Invalid(m)) => (400, err_body("bad_request", &m)),
        Err(AdminError::Unsupported(m)) => {
            (501, err_body("unsupported", &m))
        }
        Err(AdminError::Failed(m)) => (500, err_body("admin_failed", &m)),
    }
}

fn admin_request(server: &Arc<dyn ServeBackend>, verb: AdminVerb,
                 model_ref: &str, req: &HttpRequest) -> (u16, Json) {
    let body = if req.body.is_empty() {
        Json::obj(vec![])
    } else {
        let Ok(text) = std::str::from_utf8(&req.body) else {
            return (400,
                    err_body("bad_input", "body is not valid UTF-8"));
        };
        match jsonic::parse(text) {
            Ok(j) => j,
            Err(e) => {
                return (
                    400,
                    err_body("bad_input",
                             &format!("malformed JSON: {e}")),
                )
            }
        }
    };
    match build_admin_action(verb, model_ref, body) {
        Ok(action) => admin_result_body(server.admin(action)),
        Err(msg) => (400, err_body("bad_input", &msg)),
    }
}

/// Resolve the client deadline: header first, `deadline_ms` JSON field
/// second. `Err` = unparseable (400).
fn parse_deadline(req: &HttpRequest, body: &Json)
                  -> std::result::Result<Option<Duration>, String> {
    let ms = if let Some(h) = req.header(DEADLINE_HEADER) {
        Some(h.trim().parse::<f64>().map_err(|_| {
            format!("invalid {DEADLINE_HEADER} header `{h}`")
        })?)
    } else if let Some(j) = body.get("deadline_ms") {
        Some(j.as_f64().ok_or_else(|| {
            "field `deadline_ms` must be a number".to_string()
        })?)
    } else {
        None
    };
    match ms {
        None => Ok(None),
        // clamp: Duration::from_secs_f64 panics near f64::MAX and
        // Instant addition can overflow, so a huge-but-finite deadline
        // must not be able to kill the handler thread
        Some(v) if v.is_finite() && v >= 0.0 => Ok(Some(
            Duration::from_secs_f64(v.min(MAX_DEADLINE_MS) / 1e3),
        )),
        Some(v) => Err(format!(
            "deadline must be a finite non-negative ms count, got {v}"
        )),
    }
}

fn predict(server: &Arc<dyn ServeBackend>, name: &str,
           req: &HttpRequest) -> (u16, Json) {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return (400, err_body("bad_input", "body is not valid UTF-8"));
    };
    let body = match jsonic::parse(text) {
        Ok(j) => j,
        Err(e) => {
            return (400,
                    err_body("bad_input", &format!("malformed JSON: {e}")))
        }
    };
    let Some(input) = body.get("input").and_then(|j| j.as_f32_vec())
    else {
        return (
            400,
            err_body("bad_input",
                     "body must carry an `input` array of numbers"),
        );
    };
    let deadline = match parse_deadline(req, &body) {
        Ok(d) => d.map(|d| req.arrived + d),
        Err(msg) => return (400, err_body("bad_input", &msg)),
    };
    match server.predict(name, &input, deadline) {
        Ok(out) => (
            200,
            Json::obj(vec![
                ("model", Json::str(name)),
                ("output", Json::from_f32s(&out)),
            ]),
        ),
        Err(PredictError::UnknownModel(m)) => {
            (404, err_body("unknown_model", &m))
        }
        Err(PredictError::BadInput(m)) => {
            (400, err_body("bad_input", &m))
        }
        Err(PredictError::Deadline(m)) => {
            (429, err_body("deadline_exceeded", &m))
        }
        Err(PredictError::Unavailable(code, m)) => {
            (503, err_body(code, &m))
        }
        Err(PredictError::Failed(m)) => {
            (500, err_body("exec_failed", &m))
        }
    }
}

// ---------------------------------------------------------------- client

/// Minimal blocking HTTP/1.1 client over one keep-alive connection — the
/// load harness's and smoke tests' counterpart to [`HttpFront`].
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    host: String,
}

impl HttpClient {
    pub fn connect(addr: &str) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("serve: connect http to {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .ok();
        let reader = BufReader::new(
            stream.try_clone().context("serve: clone client stream")?,
        );
        Ok(HttpClient { reader, writer: stream, host: addr.to_string() })
    }

    /// One request/response round trip; returns `(status, body)`.
    /// `deadline_ms` is sent as the [`DEADLINE_HEADER`] header.
    pub fn request(&mut self, method: &str, path: &str,
                   body: Option<&str>, deadline_ms: Option<f64>)
                   -> Result<(u16, String)> {
        let mut msg =
            format!("{method} {path} HTTP/1.1\r\nhost: {}\r\n", self.host);
        if let Some(ms) = deadline_ms {
            msg.push_str(&format!("{DEADLINE_HEADER}: {ms}\r\n"));
        }
        match body {
            Some(b) => {
                msg.push_str(&format!(
                    "content-type: application/json\r\n\
                     content-length: {}\r\n\r\n",
                    b.len()
                ));
                msg.push_str(b);
            }
            None => msg.push_str("\r\n"),
        }
        self.writer
            .write_all(msg.as_bytes())
            .context("serve: send http request")?;
        self.writer.flush().ok();
        read_client_response(&mut self.reader)
    }

    pub fn get(&mut self, path: &str) -> Result<(u16, String)> {
        self.request("GET", path, None, None)
    }

    /// POST a predict body for `model`.
    pub fn predict(&mut self, model: &str, body: &str,
                   deadline_ms: Option<f64>) -> Result<(u16, String)> {
        self.request(
            "POST",
            &format!("/v1/models/{model}:predict"),
            Some(body),
            deadline_ms,
        )
    }
}

fn read_client_response(r: &mut BufReader<TcpStream>)
                        -> Result<(u16, String)> {
    let mut line = String::new();
    let n = r.read_line(&mut line).context("serve: read status line")?;
    if n == 0 {
        return Err(anyhow!("serve: server closed the connection"));
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            anyhow!("serve: bad status line `{}`", line.trim())
        })?;
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        let n = r.read_line(&mut h).context("serve: read header")?;
        if n == 0 {
            return Err(anyhow!("serve: connection closed mid-headers"));
        }
        let t = h.trim_end_matches(|c| c == '\r' || c == '\n');
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v
                    .trim()
                    .parse()
                    .context("serve: bad content-length")?;
            }
        }
    }
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("serve: read body")?;
    Ok((status, String::from_utf8(body)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_header_beats_json_field_and_validates() {
        let req = |hdr: Option<&str>| HttpRequest {
            method: "POST".into(),
            path: "/p".into(),
            headers: hdr
                .map(|v| vec![(DEADLINE_HEADER.to_string(), v.to_string())])
                .unwrap_or_default(),
            body: Vec::new(),
            arrived: Instant::now(),
        };
        let body = jsonic::parse(r#"{"deadline_ms": 250}"#).unwrap();
        assert_eq!(parse_deadline(&req(None), &body).unwrap(),
                   Some(Duration::from_millis(250)));
        assert_eq!(parse_deadline(&req(Some("50")), &body).unwrap(),
                   Some(Duration::from_millis(50)));
        assert_eq!(
            parse_deadline(&req(None), &jsonic::parse("{}").unwrap())
                .unwrap(),
            None
        );
        assert!(parse_deadline(&req(Some("soon")), &body).is_err());
        assert!(parse_deadline(&req(Some("-4")), &body).is_err());
        let bad = jsonic::parse(r#"{"deadline_ms": "soon"}"#).unwrap();
        assert!(parse_deadline(&req(None), &bad).is_err());
        // huge-but-finite deadlines clamp instead of panicking the
        // handler in Duration::from_secs_f64 / Instant addition
        let huge =
            parse_deadline(&req(Some("1e300")), &body).unwrap().unwrap();
        assert_eq!(huge,
                   Duration::from_secs_f64(MAX_DEADLINE_MS / 1e3));
    }

    #[test]
    fn error_bodies_are_json() {
        let j = err_body("bad_input", "nope");
        assert_eq!(j.at("error").as_str(), Some("bad_input"));
        assert_eq!(j.at("message").as_str(), Some("nope"));
    }

    #[test]
    fn admin_targets_resolve_and_failures_map_to_statuses() {
        // the path qualifier wins over the body field
        let body = jsonic::parse(r#"{"version":"v2"}"#).unwrap();
        assert_eq!(parse_admin_target("m@v9", &body).unwrap(),
                   ("m".to_string(), "v9".to_string()));
        assert_eq!(parse_admin_target("m", &body).unwrap(),
                   ("m".to_string(), "v2".to_string()));
        // no version anywhere: a 400 with an actionable message
        let err =
            parse_admin_target("m", &Json::obj(vec![])).unwrap_err();
        assert!(err.contains("name@version"), "{err}");
        // load's spec is the body itself
        let spec = jsonic::parse(r#"{"version":"v2","k":1}"#).unwrap();
        match build_admin_action(AdminVerb::Load, "m", spec).unwrap() {
            AdminAction::Load { name, version, spec } => {
                assert_eq!((name.as_str(), version.as_str()), ("m", "v2"));
                assert_eq!(spec.at("k").as_f64(), Some(1.0));
            }
            other => panic!("wrong action: {other:?}"),
        }
        // status mapping shared by both fronts
        let (s, j) =
            admin_result_body(Err(AdminError::Conflict("busy".into())));
        assert_eq!(s, 409);
        assert_eq!(j.at("error").as_str(), Some("conflict"));
        assert_eq!(
            admin_result_body(
                Err(AdminError::NotFound("x".into()))).0, 404);
        assert_eq!(
            admin_result_body(
                Err(AdminError::Unsupported("x".into()))).0, 501);
        assert_eq!(
            admin_result_body(
                Err(AdminError::Failed("x".into()))).0, 500);
        assert_eq!(admin_result_body(Ok(Json::obj(vec![]))).0, 200);
    }
}
