//! Binary wire front over any [`ServeBackend`]: the framed counterpart
//! of [`HttpFront`](super::super::http::HttpFront), sharing its
//! concurrency model (one accept thread, one handler thread per live
//! keep-alive connection, bounded by [`WireConfig::max_conns`]) and its
//! exact error surface — every [`PredictError`] variant maps to the
//! same status/code pair the HTTP front answers, carried in an `Error`
//! frame instead of a JSON body.
//!
//! What changes is the request path: a `Predict` frame arrives with raw
//! little-endian tensor bytes and may batch up to
//! [`MAX_FRAME_SAMPLES`](super::frame::MAX_FRAME_SAMPLES) samples.
//! Batched samples are dispatched to the backend concurrently (one
//! `backend.predict` per sample, scoped threads), so a coalescing
//! [`Server`](super::super::Server) sees the whole batch at once — the
//! same fan-out shape `HttpReplica` uses for shard hops. The first
//! per-sample error (in request order) fails the whole frame with one
//! `Error` frame, mirroring shard semantics.
//!
//! Framing errors close the connection: after a bad magic, version, or
//! length there is no way to find the next frame boundary, so the
//! server answers one `Error` frame (400 `bad_frame`) and hangs up. A
//! *well-framed* body that fails to decode (400 `bad_input`) keeps the
//! connection, like an HTTP 400 — the stream is still in sync.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::jsonic::Json;

use super::super::http::{
    admin_result_body, build_admin_action, models_body, AdminVerb,
    PredictError, ServeBackend, MAX_DEADLINE_MS,
};
use super::frame::{
    decode_predict, encode_error, encode_predict_response,
    encode_status_json, read_frame, write_frame, Frame, FrameType,
    WireError,
};

/// Wire-front knobs — the same shape as
/// [`HttpConfig`](super::super::HttpConfig), with the conventional
/// binary port one above the HTTP default.
#[derive(Debug, Clone)]
pub struct WireConfig {
    /// bind address; port 0 picks an ephemeral port (see
    /// [`WireServer::addr`])
    pub addr: String,
    /// max concurrent connections (each owns one handler thread);
    /// excess connections get an immediate 503 `Error` frame
    pub max_conns: usize,
    /// per-connection socket read/write timeout
    pub io_timeout: Duration,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            addr: "127.0.0.1:9090".to_string(),
            max_conns: 256,
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// A running wire front. Dropping (or [`shutdown`](WireServer::shutdown))
/// stops the accept loop and joins every connection handler; the
/// backend keeps running and is shut down separately.
pub struct WireServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WireServer {
    /// Bind `cfg.addr` and start serving `server` over the wire
    /// protocol. Any [`ServeBackend`] works: an `Arc<Server>` (single
    /// process) or an `Arc<Router>` (cluster routing tier) — typically
    /// the same `Arc` an [`HttpFront`](super::super::HttpFront) is
    /// already serving.
    pub fn start<B>(server: Arc<B>, cfg: WireConfig) -> Result<WireServer>
    where
        B: ServeBackend + 'static,
    {
        let backend: Arc<dyn ServeBackend> = server;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("serve: bind wire on {}", cfg.addr))?;
        let addr = listener.local_addr().context("serve: local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("lutq-wire-accept".to_string())
                .spawn(move || {
                    accept_loop(&listener, &stop, &backend, &conns, &cfg)
                })
                .context("serve: spawn wire accept thread")?
        };
        Ok(WireServer { addr, stop, accept: Some(accept), conns })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, then join every connection handler. Blocks until
    /// live keep-alive connections close or hit the io timeout — drop
    /// any idle [`WireClient`](super::WireClient)s first.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // the accept thread is blocked in accept(); poke it awake
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_and_join();
        }
    }
}

fn accept_loop(listener: &TcpListener, stop: &AtomicBool,
               server: &Arc<dyn ServeBackend>,
               conns: &Mutex<Vec<JoinHandle<()>>>, cfg: &WireConfig) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                // don't hot-spin on persistent accept errors (e.g. fd
                // exhaustion) — give handlers a chance to free fds
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(cfg.io_timeout));
        let _ = stream.set_write_timeout(Some(cfg.io_timeout));
        let mut guard = conns.lock().unwrap();
        // reap finished handlers so the vec tracks *live* connections
        guard.retain(|h| !h.is_finished());
        if guard.len() >= cfg.max_conns.max(1) {
            drop(guard);
            let mut stream = stream;
            let _ = write_frame(
                &mut stream,
                FrameType::Error,
                &encode_error(503, "overloaded",
                              "connection cap reached; retry later"),
            );
            continue;
        }
        let srv = Arc::clone(server);
        let spawned = std::thread::Builder::new()
            .name("lutq-wire-conn".to_string())
            .spawn(move || handle_connection(stream, &srv));
        match spawned {
            Ok(h) => guard.push(h),
            Err(_) => { /* out of threads: drop the connection */ }
        }
    }
}

fn handle_connection(stream: TcpStream,
                     server: &Arc<dyn ServeBackend>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(WireError::Eof) => return,
            Err(e) => {
                // framing violation: the stream cannot be resynced, so
                // answer once and close (the HTTP front's Bad path)
                let _ = write_frame(
                    &mut stream,
                    FrameType::Error,
                    &encode_error(400, "bad_frame", &e.to_string()),
                );
                return;
            }
        };
        // the deadline clock's zero: the frame is fully read
        let arrived = Instant::now();
        let (ty, body, keep) = dispatch(server, &frame, arrived);
        if write_frame(&mut stream, ty, &body).is_err() || !keep {
            return;
        }
    }
}

/// Route one well-framed request; returns the reply frame and whether
/// the connection stays open.
fn dispatch(server: &Arc<dyn ServeBackend>, frame: &Frame,
            arrived: Instant) -> (FrameType, Vec<u8>, bool) {
    match frame.ty {
        FrameType::Predict => predict(server, &frame.body, arrived),
        FrameType::Models => (
            FrameType::ModelsResponse,
            encode_status_json(
                200,
                &models_body(&server.infos()).to_string(),
            ),
            true,
        ),
        FrameType::Health => {
            let (status, body) = server.healthz();
            (
                FrameType::HealthResponse,
                encode_status_json(status, &body.to_string()),
                true,
            )
        }
        FrameType::Metrics => (
            FrameType::MetricsResponse,
            encode_status_json(
                200,
                &Json::arr(server.metric_rows()).to_string(),
            ),
            true,
        ),
        FrameType::Admin => admin(server, &frame.body),
        // a client sending server-side frame types is off-protocol;
        // answer once and close like any framing violation
        FrameType::PredictResponse
        | FrameType::Error
        | FrameType::ModelsResponse
        | FrameType::HealthResponse
        | FrameType::MetricsResponse
        | FrameType::AdminResponse => (
            FrameType::Error,
            encode_error(
                400,
                "bad_frame",
                &format!("{:?} is a response frame type", frame.ty),
            ),
            false,
        ),
    }
}

/// Handle one `Admin` frame: a UTF-8 JSON body
/// `{"action":"load|unload|setDefault","name","version","spec"}`
/// routed through the same [`AdminAction`](super::super::AdminAction)
/// seam as the HTTP admin endpoints, so both fronts publish identical
/// lifecycle semantics (and identical status/code mapping). A
/// malformed body keeps the connection, like any well-framed 400.
fn admin(server: &Arc<dyn ServeBackend>,
         body: &[u8]) -> (FrameType, Vec<u8>, bool) {
    let bad = |msg: &str| {
        (FrameType::Error, encode_error(400, "bad_input", msg), true)
    };
    let Ok(text) = std::str::from_utf8(body) else {
        return bad("admin body is not UTF-8");
    };
    let json = match crate::jsonic::parse(text) {
        Ok(j) => j,
        Err(e) => return bad(&format!("malformed JSON: {e}")),
    };
    let Some(verb) = json.get("action").and_then(|j| j.as_str()) else {
        return bad("admin request needs an `action` field \
                    (load | unload | setDefault)");
    };
    let Some(verb) = AdminVerb::from_str(verb) else {
        return bad(&format!("unknown admin action `{verb}`"));
    };
    let Some(name) = json.get("name").and_then(|j| j.as_str()) else {
        return bad("admin request needs a `name` field");
    };
    // a top-level `version` qualifies the name so it survives even
    // when `spec` is a separate object without one
    let model_ref = match json.get("version").and_then(|j| j.as_str()) {
        Some(v) if !v.is_empty() && !name.contains('@') => {
            format!("{name}@{v}")
        }
        _ => name.to_string(),
    };
    // the `spec` field (for load) defaults to the whole body, matching
    // the HTTP surface where the request body *is* the loader spec
    let spec = json.get("spec").cloned().unwrap_or_else(|| json.clone());
    match build_admin_action(verb, &model_ref, spec) {
        Ok(action) => {
            let (status, reply) = admin_result_body(server.admin(action));
            (
                FrameType::AdminResponse,
                encode_status_json(status, &reply.to_string()),
                true,
            )
        }
        Err(msg) => bad(&msg),
    }
}

fn predict(server: &Arc<dyn ServeBackend>, body: &[u8],
           arrived: Instant) -> (FrameType, Vec<u8>, bool) {
    let req = match decode_predict(body) {
        Ok(r) => r,
        Err(e) => {
            // a cleanly-framed body that fails to decode is the
            // client's bug, not a stream desync — keep the connection
            return (
                FrameType::Error,
                encode_error(400, "bad_input", &e.to_string()),
                true,
            );
        }
    };
    let deadline = req.deadline_ms.map(|ms| {
        arrived
            + Duration::from_secs_f64(ms.min(MAX_DEADLINE_MS) / 1e3)
    });
    let outputs = if req.samples.len() == 1 {
        server
            .predict(&req.model, &req.samples[0], deadline)
            .map(|out| vec![out])
    } else {
        predict_batch(server, &req.model, &req.samples, deadline)
    };
    match outputs {
        Ok(rows) => match encode_predict_response(&rows) {
            Ok(b) => (FrameType::PredictResponse, b, true),
            Err(e) => (
                FrameType::Error,
                encode_error(500, "exec_failed", &e.to_string()),
                true,
            ),
        },
        Err(e) => {
            let (status, code, msg) = status_code_msg(&e);
            (FrameType::Error, encode_error(status, code, &msg), true)
        }
    }
}

/// Submit every sample of a batched frame concurrently so a coalescing
/// batcher sees the whole batch; the first error in request order
/// decides the frame, like a shard hop.
fn predict_batch(server: &Arc<dyn ServeBackend>, model: &str,
                 samples: &[Vec<f32>], deadline: Option<Instant>)
                 -> std::result::Result<Vec<Vec<f32>>, PredictError> {
    let mut slots: Vec<Option<std::result::Result<Vec<f32>,
                                                  PredictError>>> =
        (0..samples.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (slot, sample) in slots.iter_mut().zip(samples) {
            scope.spawn(move || {
                *slot = Some(server.predict(model, sample, deadline));
            });
        }
    });
    let mut rows = Vec::with_capacity(samples.len());
    for slot in slots {
        match slot.expect("scoped thread filled its slot") {
            Ok(out) => rows.push(out),
            Err(e) => return Err(e),
        }
    }
    Ok(rows)
}

/// The HTTP front's status mapping, reused verbatim for `Error`
/// frames. `Unavailable`'s message is carried without the code prefix
/// its `Display` prepends, matching the HTTP JSON body exactly.
fn status_code_msg(e: &PredictError) -> (u16, &'static str, String) {
    match e {
        PredictError::UnknownModel(m) => {
            (404, "unknown_model", m.clone())
        }
        PredictError::BadInput(m) => (400, "bad_input", m.clone()),
        PredictError::Deadline(m) => {
            (429, "deadline_exceeded", m.clone())
        }
        PredictError::Unavailable(code, m) => (503, code, m.clone()),
        PredictError::Failed(m) => (500, "exec_failed", m.clone()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_error_mapping_matches_http() {
        let cases = [
            (PredictError::UnknownModel("x".into()),
             (404, "unknown_model")),
            (PredictError::BadInput("x".into()), (400, "bad_input")),
            (PredictError::Deadline("x".into()),
             (429, "deadline_exceeded")),
            (PredictError::Unavailable("shutting_down", "x".into()),
             (503, "shutting_down")),
            (PredictError::Unavailable("no_healthy_replicas",
                                       "x".into()),
             (503, "no_healthy_replicas")),
            (PredictError::Failed("x".into()), (500, "exec_failed")),
        ];
        for (err, (status, code)) in cases {
            let (s, c, m) = status_code_msg(&err);
            assert_eq!((s, c), (status, code));
            // message carries no code prefix, like the HTTP body
            assert_eq!(m, "x");
        }
    }
}
