//! Binary framed wire protocol: the zero-copy transport next to the
//! HTTP/1.1 front.
//!
//! JSON encode/parse of full f32 tensors sits on the hot path of every
//! HTTP request; for small models the wire dominates the kernel. This
//! subsystem replaces it with length-prefixed frames carrying raw
//! little-endian tensor bytes — batched multi-sample `Predict`
//! requests, `PredictResponse` rows, typed `Error` frames with the
//! same status/code mapping as HTTP, and `Models`/`Health`/`Metrics`
//! twins so the observability surface carries over unchanged.
//!
//! - [`frame`] — the codec: header layout, [`frame::WireError`], the
//!   predict/response/error body formats, and the pre-encoding entry
//!   point [`frame::predict_frame_bytes`].
//! - [`server`] — [`WireServer`], an accept loop serving any
//!   [`ServeBackend`](super::ServeBackend) (a `Server` or a cluster
//!   `Router`), typically next to a live
//!   [`HttpFront`](super::HttpFront) on the same backend `Arc`.
//! - [`client`] — [`WireClient`], the matching pooled-friendly
//!   keep-alive client; `WireReplica` in
//!   [`cluster`](super::cluster) pools it so router → replica shard
//!   hops pay no serialization.

pub mod client;
pub mod frame;
pub mod server;

pub use client::{WireClient, WireReply};
pub use frame::{
    predict_frame_bytes, ErrorFrame, Frame, FrameType, WireError,
    MAX_FRAME_BYTES, MAX_FRAME_SAMPLES,
};
pub use server::{WireConfig, WireServer};
