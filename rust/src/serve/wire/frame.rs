//! Frame codec of the binary wire protocol: versioned, length-prefixed
//! frames with raw little-endian tensor bodies.
//!
//! Every frame is a fixed 10-byte header followed by `length` body
//! bytes:
//!
//! | off | size | field                                       |
//! |-----|------|---------------------------------------------|
//! | 0   | 4    | magic `"LQWP"`                              |
//! | 4   | 1    | protocol version ([`VERSION`])              |
//! | 5   | 1    | frame type ([`FrameType`])                  |
//! | 6   | 4    | u32 LE body length (<= [`MAX_FRAME_BYTES`]) |
//!
//! All multi-byte integers and floats are little-endian, on and off the
//! wire — tensor bodies are the raw `f32::to_le_bytes` (or i8) image of
//! the sample data, so neither side pays a per-element text encode or
//! parse. Compatibility rule: the header layout is frozen across
//! versions; a peer that sees a version byte it does not speak answers
//! one `Error` frame (400 `bad_frame`) and closes, so old clients fail
//! fast instead of mis-parsing bodies.
//!
//! Decoding is total: any byte stream — truncated, oversized, wrong
//! magic, severed mid-frame — comes back as a typed [`WireError`],
//! never a panic (the malformed-frame property test in
//! `tests/wire_serve.rs` pins this). A clean EOF *between* frames is
//! the distinguished [`WireError::Eof`], which connection loops treat
//! as the peer hanging up.

use std::io::{Read, Write};

/// First four bytes of every frame: "LQWP" (LUT-Q wire protocol).
pub const MAGIC: [u8; 4] = *b"LQWP";

/// Protocol version spoken by this build.
pub const VERSION: u8 = 1;

/// Fixed frame-header size: magic + version + type + u32 body length.
pub const HEADER_BYTES: usize = 10;

/// Hard cap on a frame body, matching the HTTP front's body cap. The
/// length field is validated *before* any allocation, so a hostile
/// 4 GiB length claim costs nothing.
pub const MAX_FRAME_BYTES: u32 = 64 * 1024 * 1024;

/// Max samples one `Predict` frame may batch. Shard hops stay far
/// below this (`RouterConfig::max_shard`); the cap bounds the server's
/// per-request fan-out, like `max_conns` bounds connections.
pub const MAX_FRAME_SAMPLES: usize = 256;

/// Fixed prefix of a `Predict` body, before the model name and data.
const PREDICT_FIXED: usize = 24;

/// Frame types. Requests are odd where they have a response twin;
/// a server answers `Predict` with `PredictResponse` or `Error`, and
/// the JSON-carrying requests with their `*Response` twin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    /// client -> server: batched predict request (tensor body)
    Predict = 0x01,
    /// server -> client: per-sample output rows (tensor body)
    PredictResponse = 0x02,
    /// server -> client: typed failure (HTTP-equivalent status + code)
    Error = 0x03,
    /// client -> server: model catalog request (empty body)
    Models = 0x04,
    /// server -> client: status + the `/v1/models` JSON text
    ModelsResponse = 0x05,
    /// client -> server: health probe (empty body)
    Health = 0x06,
    /// server -> client: status + the `/healthz` JSON text
    HealthResponse = 0x07,
    /// client -> server: metrics request (empty body)
    Metrics = 0x08,
    /// server -> client: status + the `/metrics` JSON text
    MetricsResponse = 0x09,
    /// client -> server: model-lifecycle admin request; the body is
    /// UTF-8 JSON `{"action","name","version","spec"}` matching the
    /// HTTP `POST /v1/models/{name}:load|:unload|:setDefault` surface
    Admin = 0x0A,
    /// server -> client: status + the admin endpoint's JSON body
    AdminResponse = 0x0B,
}

impl FrameType {
    pub fn from_u8(b: u8) -> Option<FrameType> {
        Some(match b {
            0x01 => FrameType::Predict,
            0x02 => FrameType::PredictResponse,
            0x03 => FrameType::Error,
            0x04 => FrameType::Models,
            0x05 => FrameType::ModelsResponse,
            0x06 => FrameType::Health,
            0x07 => FrameType::HealthResponse,
            0x08 => FrameType::Metrics,
            0x09 => FrameType::MetricsResponse,
            0x0A => FrameType::Admin,
            0x0B => FrameType::AdminResponse,
            _ => return None,
        })
    }
}

/// Why a byte stream failed to yield a frame (or a body failed to
/// decode). Every variant is a clean, typed error — the parser never
/// panics on wire input.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// clean end of stream between frames (the peer hung up)
    Eof,
    /// first four bytes are not [`MAGIC`]
    BadMagic([u8; 4]),
    /// version byte this build does not speak
    BadVersion(u8),
    /// unknown frame-type byte
    BadType(u8),
    /// declared body length exceeds [`MAX_FRAME_BYTES`]
    TooLarge(u32),
    /// the stream ended (or the socket failed) mid-frame
    Truncated(String),
    /// a well-framed body that does not decode as its frame type
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Eof => write!(f, "connection closed"),
            WireError::BadMagic(m) => {
                write!(f, "bad magic {m:02x?} (want {MAGIC:02x?})")
            }
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} \
                           (this build speaks {VERSION})")
            }
            WireError::BadType(t) => {
                write!(f, "unknown frame type {t:#04x}")
            }
            WireError::TooLarge(n) => {
                write!(f, "declared body of {n} bytes exceeds the \
                           {MAX_FRAME_BYTES}-byte frame cap")
            }
            WireError::Truncated(m) => write!(f, "truncated frame: {m}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One decoded frame: its type and raw body bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub ty: FrameType,
    pub body: Vec<u8>,
}

/// Read one frame. Returns [`WireError::Eof`] only when the stream
/// ends cleanly *between* frames; an end (or socket error) inside a
/// frame is [`WireError::Truncated`]. The length field is validated
/// against [`MAX_FRAME_BYTES`] before the body is allocated.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut hdr = [0u8; HEADER_BYTES];
    let mut got = 0usize;
    while got < HEADER_BYTES {
        match r.read(&mut hdr[got..]) {
            Ok(0) if got == 0 => return Err(WireError::Eof),
            Ok(0) => {
                return Err(WireError::Truncated(format!(
                    "stream ended {got} bytes into the \
                     {HEADER_BYTES}-byte header"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // an io error (idle timeout, reset) before any header byte
            // is indistinguishable from the peer hanging up: treat it
            // as a clean close, like the HTTP front's read loop
            Err(_) if got == 0 => return Err(WireError::Eof),
            Err(e) => {
                return Err(WireError::Truncated(format!(
                    "io error mid-header: {e}"
                )))
            }
        }
    }
    if hdr[..4] != MAGIC {
        return Err(WireError::BadMagic([hdr[0], hdr[1], hdr[2], hdr[3]]));
    }
    if hdr[4] != VERSION {
        return Err(WireError::BadVersion(hdr[4]));
    }
    let Some(ty) = FrameType::from_u8(hdr[5]) else {
        return Err(WireError::BadType(hdr[5]));
    };
    let len = u32::from_le_bytes([hdr[6], hdr[7], hdr[8], hdr[9]]);
    if len > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|e| {
        WireError::Truncated(format!(
            "stream ended inside a {len}-byte {ty:?} body: {e}"
        ))
    })?;
    Ok(Frame { ty, body })
}

/// Assemble a complete frame (header + body) as one buffer, so writers
/// hand the socket a single contiguous write.
pub fn frame_bytes(ty: FrameType,
                   body: &[u8]) -> Result<Vec<u8>, WireError> {
    if body.len() > MAX_FRAME_BYTES as usize {
        return Err(WireError::TooLarge(
            u32::try_from(body.len()).unwrap_or(u32::MAX),
        ));
    }
    let mut out = Vec::with_capacity(HEADER_BYTES + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(ty as u8);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    Ok(out)
}

/// Write one frame as a single buffered write.
pub fn write_frame<W: Write>(w: &mut W, ty: FrameType,
                             body: &[u8]) -> std::io::Result<()> {
    let bytes = frame_bytes(ty, body).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, e)
    })?;
    w.write_all(&bytes)?;
    w.flush()
}

// ------------------------------------------------------------- predict

/// Sample element encoding of a `Predict` body.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 4 bytes per element, `f32::to_le_bytes`
    F32 = 0,
    /// 1 byte per element; the server dequantizes as `v as f32 * scale`
    I8 = 1,
}

/// A decoded `Predict` body. Body layout after the frame header:
///
/// | off  | size | field                                          |
/// |------|------|------------------------------------------------|
/// | 0    | 1    | dtype: 0 = f32 LE, 1 = i8                      |
/// | 1    | 1    | deadline flag: 0 = none, 1 = field at off 4    |
/// | 2    | 2    | u16 LE model-name byte length `M`              |
/// | 4    | 8    | f64 LE deadline in ms (ignored when flag = 0)  |
/// | 12   | 4    | f32 LE dequant scale (i8 only; 1.0 for f32)    |
/// | 16   | 4    | u32 LE `n_samples` (1..=[`MAX_FRAME_SAMPLES`]) |
/// | 20   | 4    | u32 LE elements per sample (>= 1)              |
/// | 24   | M    | model name (UTF-8)                             |
/// | 24+M | rest | sample data: `n*e` f32 LE or `n*e` i8 bytes    |
///
/// The deadline clock starts when the server finishes reading the
/// frame, mirroring the HTTP front's `x-lutq-deadline-ms` semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictRequest {
    pub model: String,
    pub deadline_ms: Option<f64>,
    pub dtype: Dtype,
    /// samples as f32 (i8 bodies are dequantized by `scale` on decode,
    /// so every [`super::server::WireServer`] backend sees the same
    /// `&[f32]` seam as HTTP)
    pub samples: Vec<Vec<f32>>,
}

fn predict_prefix(model: &str, dtype: Dtype, scale: f32,
                  deadline_ms: Option<f64>, n_samples: usize,
                  elems: usize) -> Result<Vec<u8>, WireError> {
    if model.len() > u16::MAX as usize {
        return Err(WireError::Malformed(format!(
            "model name of {} bytes exceeds the u16 length field",
            model.len()
        )));
    }
    if n_samples == 0 || n_samples > MAX_FRAME_SAMPLES {
        return Err(WireError::Malformed(format!(
            "{n_samples} samples outside 1..={MAX_FRAME_SAMPLES}"
        )));
    }
    if elems == 0 || elems > u32::MAX as usize {
        return Err(WireError::Malformed(format!(
            "{elems} elements per sample outside the u32 field"
        )));
    }
    if let Some(ms) = deadline_ms {
        if !ms.is_finite() || ms < 0.0 {
            return Err(WireError::Malformed(format!(
                "deadline must be a finite non-negative ms count, \
                 got {ms}"
            )));
        }
    }
    let mut out = Vec::with_capacity(PREDICT_FIXED + model.len());
    out.push(dtype as u8);
    out.push(u8::from(deadline_ms.is_some()));
    out.extend_from_slice(&(model.len() as u16).to_le_bytes());
    out.extend_from_slice(&deadline_ms.unwrap_or(0.0).to_le_bytes());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&(n_samples as u32).to_le_bytes());
    out.extend_from_slice(&(elems as u32).to_le_bytes());
    out.extend_from_slice(model.as_bytes());
    Ok(out)
}

fn uniform_len<T>(samples: &[&[T]]) -> Result<usize, WireError> {
    let elems = samples.first().map_or(0, |s| s.len());
    if samples.iter().any(|s| s.len() != elems) {
        return Err(WireError::Malformed(
            "ragged batch: samples differ in length".to_string(),
        ));
    }
    Ok(elems)
}

/// Encode a `Predict` body with raw f32 LE samples.
pub fn encode_predict_f32(model: &str, samples: &[&[f32]],
                          deadline_ms: Option<f64>)
                          -> Result<Vec<u8>, WireError> {
    let elems = uniform_len(samples)?;
    let mut out = predict_prefix(model, Dtype::F32, 1.0, deadline_ms,
                                 samples.len(), elems)?;
    out.reserve(samples.len() * elems * 4);
    for s in samples {
        for v in *s {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    if out.len() > MAX_FRAME_BYTES as usize {
        return Err(WireError::TooLarge(
            u32::try_from(out.len()).unwrap_or(u32::MAX),
        ));
    }
    Ok(out)
}

/// Encode a `Predict` body with i8 samples; the server reconstructs
/// each element as `v as f32 * scale`.
pub fn encode_predict_i8(model: &str, samples: &[&[i8]], scale: f32,
                         deadline_ms: Option<f64>)
                         -> Result<Vec<u8>, WireError> {
    if !scale.is_finite() {
        return Err(WireError::Malformed(format!(
            "i8 dequant scale must be finite, got {scale}"
        )));
    }
    let elems = uniform_len(samples)?;
    let mut out = predict_prefix(model, Dtype::I8, scale, deadline_ms,
                                 samples.len(), elems)?;
    out.reserve(samples.len() * elems);
    for s in samples {
        out.extend(s.iter().map(|v| *v as u8));
    }
    if out.len() > MAX_FRAME_BYTES as usize {
        return Err(WireError::TooLarge(
            u32::try_from(out.len()).unwrap_or(u32::MAX),
        ));
    }
    Ok(out)
}

/// A complete f32 `Predict` frame (header + body) in one buffer — the
/// pre-encoded form the load harness and replica shard hops send, so
/// the measured path pays zero per-request encoding.
pub fn predict_frame_bytes(model: &str, samples: &[&[f32]],
                           deadline_ms: Option<f64>)
                           -> Result<Vec<u8>, WireError> {
    frame_bytes(FrameType::Predict,
                &encode_predict_f32(model, samples, deadline_ms)?)
}

/// Decode a `Predict` body (see [`PredictRequest`] for the layout).
/// The body length must account for every declared byte exactly.
pub fn decode_predict(body: &[u8]) -> Result<PredictRequest, WireError> {
    if body.len() < PREDICT_FIXED {
        return Err(WireError::Malformed(format!(
            "predict body of {} bytes is shorter than the {}-byte \
             fixed prefix",
            body.len(),
            PREDICT_FIXED
        )));
    }
    let dtype = match body[0] {
        0 => Dtype::F32,
        1 => Dtype::I8,
        b => {
            return Err(WireError::Malformed(format!(
                "unknown dtype byte {b}"
            )))
        }
    };
    let deadline_ms = match body[1] {
        0 => None,
        1 => {
            let ms = f64::from_le_bytes(
                body[4..12].try_into().expect("8 bytes"),
            );
            if !ms.is_finite() || ms < 0.0 {
                return Err(WireError::Malformed(format!(
                    "deadline must be a finite non-negative ms \
                     count, got {ms}"
                )));
            }
            Some(ms)
        }
        b => {
            return Err(WireError::Malformed(format!(
                "deadline flag must be 0 or 1, got {b}"
            )))
        }
    };
    let name_len =
        u16::from_le_bytes([body[2], body[3]]) as usize;
    let scale =
        f32::from_le_bytes(body[12..16].try_into().expect("4 bytes"));
    if !scale.is_finite() {
        return Err(WireError::Malformed(format!(
            "dequant scale must be finite, got {scale}"
        )));
    }
    let n = u32::from_le_bytes(body[16..20].try_into().expect("4 bytes"))
        as usize;
    let elems =
        u32::from_le_bytes(body[20..24].try_into().expect("4 bytes"))
            as usize;
    if n == 0 || n > MAX_FRAME_SAMPLES {
        return Err(WireError::Malformed(format!(
            "{n} samples outside 1..={MAX_FRAME_SAMPLES}"
        )));
    }
    if elems == 0 {
        return Err(WireError::Malformed(
            "zero elements per sample".to_string(),
        ));
    }
    let esize = match dtype {
        Dtype::F32 => 4usize,
        Dtype::I8 => 1,
    };
    let data_len = n
        .checked_mul(elems)
        .and_then(|x| x.checked_mul(esize))
        .ok_or_else(|| {
            WireError::Malformed(format!(
                "sample dims {n}x{elems} overflow"
            ))
        })?;
    let want = PREDICT_FIXED + name_len + data_len;
    if body.len() != want {
        return Err(WireError::Malformed(format!(
            "body length {} does not match the declared {} \
             ({n} samples x {elems} elems + {name_len}-byte name)",
            body.len(),
            want
        )));
    }
    let name_end = PREDICT_FIXED + name_len;
    let model = std::str::from_utf8(&body[PREDICT_FIXED..name_end])
        .map_err(|_| {
            WireError::Malformed("model name is not UTF-8".to_string())
        })?
        .to_string();
    let data = &body[name_end..];
    let samples: Vec<Vec<f32>> = match dtype {
        Dtype::F32 => data
            .chunks_exact(elems * 4)
            .map(|row| {
                row.chunks_exact(4)
                    .map(|c| {
                        f32::from_le_bytes(
                            c.try_into().expect("4 bytes"),
                        )
                    })
                    .collect()
            })
            .collect(),
        Dtype::I8 => data
            .chunks_exact(elems)
            .map(|row| {
                row.iter().map(|&b| (b as i8) as f32 * scale).collect()
            })
            .collect(),
    };
    Ok(PredictRequest { model, deadline_ms, dtype, samples })
}

// ------------------------------------------------------------ response

/// Encode a `PredictResponse` body: u32 LE row count, u32 LE elements
/// per row, then the raw f32 LE rows in request order.
pub fn encode_predict_response(rows: &[Vec<f32>])
                               -> Result<Vec<u8>, WireError> {
    if rows.is_empty() {
        return Err(WireError::Malformed(
            "a predict response needs at least one row".to_string(),
        ));
    }
    let elems = rows[0].len();
    if rows.iter().any(|r| r.len() != elems) {
        return Err(WireError::Malformed(
            "ragged response: rows differ in length".to_string(),
        ));
    }
    let mut out = Vec::with_capacity(8 + rows.len() * elems * 4);
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    out.extend_from_slice(&(elems as u32).to_le_bytes());
    for row in rows {
        for v in row {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    if out.len() > MAX_FRAME_BYTES as usize {
        return Err(WireError::TooLarge(
            u32::try_from(out.len()).unwrap_or(u32::MAX),
        ));
    }
    Ok(out)
}

/// Decode a `PredictResponse` body into per-sample output rows.
pub fn decode_predict_response(body: &[u8])
                               -> Result<Vec<Vec<f32>>, WireError> {
    if body.len() < 8 {
        return Err(WireError::Malformed(format!(
            "response body of {} bytes lacks the 8-byte prefix",
            body.len()
        )));
    }
    let n = u32::from_le_bytes(body[0..4].try_into().expect("4 bytes"))
        as usize;
    let elems =
        u32::from_le_bytes(body[4..8].try_into().expect("4 bytes"))
            as usize;
    let data_len = n
        .checked_mul(elems)
        .and_then(|x| x.checked_mul(4))
        .ok_or_else(|| {
            WireError::Malformed(format!(
                "response dims {n}x{elems} overflow"
            ))
        })?;
    if body.len() != 8 + data_len {
        return Err(WireError::Malformed(format!(
            "response body length {} does not match the declared \
             {n} rows x {elems} elems",
            body.len()
        )));
    }
    Ok(body[8..]
        .chunks_exact(elems.max(1) * 4)
        .map(|row| {
            row.chunks_exact(4)
                .map(|c| {
                    f32::from_le_bytes(c.try_into().expect("4 bytes"))
                })
                .collect()
        })
        .collect())
}

// --------------------------------------------------------------- error

/// A decoded `Error` body: the same status/code mapping as the HTTP
/// front's JSON error bodies (`status` is the HTTP-equivalent code,
/// `code` the machine-readable string like `deadline_exceeded`).
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorFrame {
    pub status: u16,
    pub code: String,
    pub message: String,
}

/// Encode an `Error` body: u16 LE status, u16 LE code length, the code
/// bytes, then the message as the rest of the body.
pub fn encode_error(status: u16, code: &str, message: &str) -> Vec<u8> {
    let code = &code.as_bytes()[..code.len().min(u16::MAX as usize)];
    let mut out = Vec::with_capacity(4 + code.len() + message.len());
    out.extend_from_slice(&status.to_le_bytes());
    out.extend_from_slice(&(code.len() as u16).to_le_bytes());
    out.extend_from_slice(code);
    out.extend_from_slice(message.as_bytes());
    out
}

/// Decode an `Error` body. Code/message are decoded lossily — a
/// garbled error frame should still surface as an error, not fail.
pub fn decode_error(body: &[u8]) -> Result<ErrorFrame, WireError> {
    if body.len() < 4 {
        return Err(WireError::Malformed(format!(
            "error body of {} bytes lacks the 4-byte prefix",
            body.len()
        )));
    }
    let status = u16::from_le_bytes([body[0], body[1]]);
    let code_len = u16::from_le_bytes([body[2], body[3]]) as usize;
    let code_end = 4 + code_len;
    if body.len() < code_end {
        return Err(WireError::Malformed(format!(
            "error body of {} bytes cannot hold a {code_len}-byte code",
            body.len()
        )));
    }
    Ok(ErrorFrame {
        status,
        code: String::from_utf8_lossy(&body[4..code_end]).into_owned(),
        message: String::from_utf8_lossy(&body[code_end..]).into_owned(),
    })
}

// --------------------------------------------------- status+JSON frames

/// Encode a `{Models,Health,Metrics}Response` body: u16 LE status, then
/// the same JSON text the HTTP endpoint would answer. These are not hot
/// paths; sharing the JSON shape keeps the two fronts' observability
/// surfaces identical.
pub fn encode_status_json(status: u16, json: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 + json.len());
    out.extend_from_slice(&status.to_le_bytes());
    out.extend_from_slice(json.as_bytes());
    out
}

/// Decode a status+JSON response body.
pub fn decode_status_json(body: &[u8])
                          -> Result<(u16, String), WireError> {
    if body.len() < 2 {
        return Err(WireError::Malformed(format!(
            "status body of {} bytes lacks the 2-byte prefix",
            body.len()
        )));
    }
    let status = u16::from_le_bytes([body[0], body[1]]);
    let text = std::str::from_utf8(&body[2..]).map_err(|_| {
        WireError::Malformed("status body is not UTF-8".to_string())
    })?;
    Ok((status, text.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(ty: FrameType, body: &[u8]) -> Frame {
        let bytes = frame_bytes(ty, body).unwrap();
        let mut r: &[u8] = &bytes;
        let f = read_frame(&mut r).unwrap();
        assert!(r.is_empty(), "frame consumed exactly");
        f
    }

    #[test]
    fn predict_f32_roundtrips_bitwise() {
        let a = vec![0.25f32, -1.5, f32::MIN_POSITIVE, 3.0e7];
        let b = vec![0.0f32, -0.0, 1.0, -2.5];
        let body = encode_predict_f32(
            "mlp", &[&a, &b], Some(125.5)).unwrap();
        let f = roundtrip(FrameType::Predict, &body);
        assert_eq!(f.ty, FrameType::Predict);
        let req = decode_predict(&f.body).unwrap();
        assert_eq!(req.model, "mlp");
        assert_eq!(req.deadline_ms, Some(125.5));
        assert_eq!(req.dtype, Dtype::F32);
        assert_eq!(req.samples.len(), 2);
        for (got, want) in req.samples[0].iter().zip(&a) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        for (got, want) in req.samples[1].iter().zip(&b) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn predict_i8_dequantizes_with_scale() {
        let q: Vec<i8> = vec![-128, -1, 0, 1, 127];
        let body =
            encode_predict_i8("m", &[&q], 0.05, None).unwrap();
        let req = decode_predict(&body).unwrap();
        assert_eq!(req.deadline_ms, None);
        assert_eq!(req.dtype, Dtype::I8);
        for (got, want) in req.samples[0].iter().zip(&q) {
            assert_eq!(got.to_bits(),
                       (*want as f32 * 0.05).to_bits());
        }
    }

    #[test]
    fn predict_response_roundtrips_bitwise() {
        let rows = vec![vec![1.0f32, -2.25, 0.5], vec![9.0, 0.0, -0.0]];
        let body = encode_predict_response(&rows).unwrap();
        let got = decode_predict_response(&body).unwrap();
        assert_eq!(got.len(), 2);
        for (g, w) in got.iter().flatten().zip(rows.iter().flatten()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        assert!(encode_predict_response(&[]).is_err());
        assert!(encode_predict_response(
            &[vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn error_and_status_json_roundtrip() {
        let body = encode_error(429, "deadline_exceeded", "too slow");
        let e = decode_error(&body).unwrap();
        assert_eq!(e.status, 429);
        assert_eq!(e.code, "deadline_exceeded");
        assert_eq!(e.message, "too slow");
        let body = encode_status_json(200, "{\"status\":\"ok\"}");
        let (status, text) = decode_status_json(&body).unwrap();
        assert_eq!(status, 200);
        assert_eq!(text, "{\"status\":\"ok\"}");
        assert!(decode_error(&[0]).is_err());
        assert!(decode_status_json(&[9]).is_err());
    }

    #[test]
    fn header_violations_are_typed_errors() {
        // empty stream: clean Eof
        let mut r: &[u8] = &[];
        assert_eq!(read_frame(&mut r), Err(WireError::Eof));
        // wrong magic
        let mut bytes = frame_bytes(FrameType::Health, &[]).unwrap();
        bytes[0] = b'X';
        let mut r: &[u8] = &bytes;
        assert!(matches!(read_frame(&mut r),
                         Err(WireError::BadMagic(_))));
        // wrong version
        let mut bytes = frame_bytes(FrameType::Health, &[]).unwrap();
        bytes[4] = 99;
        let mut r: &[u8] = &bytes;
        assert_eq!(read_frame(&mut r), Err(WireError::BadVersion(99)));
        // unknown frame type
        let mut bytes = frame_bytes(FrameType::Health, &[]).unwrap();
        bytes[5] = 0xee;
        let mut r: &[u8] = &bytes;
        assert_eq!(read_frame(&mut r), Err(WireError::BadType(0xee)));
        // hostile length claim: rejected before any allocation
        let mut bytes = frame_bytes(FrameType::Health, &[]).unwrap();
        bytes[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut r: &[u8] = &bytes;
        assert_eq!(read_frame(&mut r),
                   Err(WireError::TooLarge(u32::MAX)));
        // severed mid-header and mid-body
        let bytes =
            frame_bytes(FrameType::Predict, &[1, 2, 3, 4]).unwrap();
        let mut r: &[u8] = &bytes[..5];
        assert!(matches!(read_frame(&mut r),
                         Err(WireError::Truncated(_))));
        let mut r: &[u8] = &bytes[..HEADER_BYTES + 2];
        assert!(matches!(read_frame(&mut r),
                         Err(WireError::Truncated(_))));
    }

    #[test]
    fn malformed_predict_bodies_are_rejected() {
        // ragged batches never encode
        let a = [1.0f32, 2.0];
        let b = [1.0f32];
        assert!(matches!(
            encode_predict_f32("m", &[&a, &b], None),
            Err(WireError::Malformed(_))
        ));
        // zero samples
        assert!(encode_predict_f32("m", &[], None).is_err());
        // batch cap
        let one = [0.0f32];
        let big: Vec<&[f32]> =
            (0..MAX_FRAME_SAMPLES + 1).map(|_| &one[..]).collect();
        assert!(encode_predict_f32("m", &big, None).is_err());
        // non-finite deadline
        assert!(
            encode_predict_f32("m", &[&a], Some(f64::NAN)).is_err()
        );
        // decode: truncated fixed prefix
        assert!(decode_predict(&[0, 0, 0]).is_err());
        // decode: body length disagrees with the declared dims
        let mut body =
            encode_predict_f32("m", &[&a], None).unwrap();
        body.pop();
        assert!(matches!(decode_predict(&body),
                         Err(WireError::Malformed(_))));
        // decode: unknown dtype byte
        let mut body = encode_predict_f32("m", &[&a], None).unwrap();
        body[0] = 7;
        assert!(decode_predict(&body).is_err());
        // decode: non-utf8 model name
        let mut body = encode_predict_f32("mm", &[&a], None).unwrap();
        body[PREDICT_FIXED] = 0xff;
        body[PREDICT_FIXED + 1] = 0xfe;
        assert!(decode_predict(&body).is_err());
    }

    #[test]
    fn pipelined_frames_decode_in_order() {
        let a = [0.5f32; 3];
        let mut stream =
            predict_frame_bytes("m", &[&a], None).unwrap();
        stream.extend(frame_bytes(FrameType::Health, &[]).unwrap());
        stream.extend(
            predict_frame_bytes("n", &[&a, &a], Some(10.0)).unwrap(),
        );
        let mut r: &[u8] = &stream;
        let f1 = read_frame(&mut r).unwrap();
        assert_eq!(f1.ty, FrameType::Predict);
        assert_eq!(decode_predict(&f1.body).unwrap().model, "m");
        assert_eq!(read_frame(&mut r).unwrap().ty, FrameType::Health);
        let f3 = read_frame(&mut r).unwrap();
        let req = decode_predict(&f3.body).unwrap();
        assert_eq!(req.model, "n");
        assert_eq!(req.samples.len(), 2);
        assert_eq!(read_frame(&mut r), Err(WireError::Eof));
    }
}
