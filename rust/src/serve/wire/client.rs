//! Minimal blocking wire-protocol client over one keep-alive
//! connection — the load harness's and smoke tests' counterpart to
//! [`WireServer`](super::WireServer), like
//! [`HttpClient`](super::super::HttpClient) is for the HTTP front.
//!
//! The hot path is [`WireClient::request_frame`]: it takes a fully
//! pre-encoded predict frame (see
//! [`predict_frame_bytes`](super::frame::predict_frame_bytes)), so a
//! benchmark or a pooled replica hop pays one `write_all` and one
//! framed read per request — no per-request encoding at all.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::frame::{
    decode_error, decode_predict_response, decode_status_json,
    encode_predict_f32, frame_bytes, read_frame, write_frame,
    ErrorFrame, FrameType,
};

/// Outcome of a predict round trip that got a well-formed answer:
/// either the output rows, or the server's typed refusal (the wire
/// analog of a non-200 HTTP status — deadline 429s, unknown model
/// 404s, overload 503s land here, not in `Err`).
#[derive(Debug, Clone, PartialEq)]
pub enum WireReply {
    /// one output row per request sample, in order
    Outputs(Vec<Vec<f32>>),
    /// the server answered an `Error` frame
    Refused(ErrorFrame),
}

/// One keep-alive wire connection.
pub struct WireClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl WireClient {
    pub fn connect(addr: &str) -> Result<WireClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("serve: connect wire to {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .ok();
        let reader = BufReader::new(
            stream.try_clone().context("serve: clone wire stream")?,
        );
        Ok(WireClient { reader, writer: stream })
    }

    /// Predict one sample (a batch of 1).
    pub fn predict(&mut self, model: &str, sample: &[f32],
                   deadline_ms: Option<f64>) -> Result<WireReply> {
        self.predict_batch(model, &[sample], deadline_ms)
    }

    /// Predict a uniform batch of samples in one frame; on success the
    /// reply carries one output row per sample, in order.
    pub fn predict_batch(&mut self, model: &str, samples: &[&[f32]],
                         deadline_ms: Option<f64>) -> Result<WireReply> {
        let body = encode_predict_f32(model, samples, deadline_ms)
            .map_err(|e| anyhow!("serve: encode predict: {e}"))?;
        let bytes = frame_bytes(FrameType::Predict, &body)
            .map_err(|e| anyhow!("serve: frame predict: {e}"))?;
        self.request_frame(&bytes)
    }

    /// Send a pre-encoded predict frame (header + body, from
    /// [`predict_frame_bytes`](super::frame::predict_frame_bytes)) and
    /// read the reply — the zero-encode hot path.
    pub fn request_frame(&mut self,
                         frame: &[u8]) -> Result<WireReply> {
        self.writer
            .write_all(frame)
            .context("serve: send predict frame")?;
        self.writer.flush().ok();
        let reply = read_frame(&mut self.reader)
            .map_err(|e| anyhow!("serve: read reply frame: {e}"))?;
        match reply.ty {
            FrameType::PredictResponse => {
                Ok(WireReply::Outputs(
                    decode_predict_response(&reply.body).map_err(
                        |e| anyhow!("serve: bad reply body: {e}"),
                    )?,
                ))
            }
            FrameType::Error => Ok(WireReply::Refused(
                decode_error(&reply.body)
                    .map_err(|e| anyhow!("serve: bad error body: {e}"))?,
            )),
            ty => bail!("serve: unexpected reply frame {ty:?}"),
        }
    }

    /// `GET /v1/models` equivalent; returns `(status, JSON text)`.
    pub fn models(&mut self) -> Result<(u16, String)> {
        self.status_json(FrameType::Models, FrameType::ModelsResponse,
                         &[])
    }

    /// `GET /healthz` equivalent; returns `(status, JSON text)`.
    pub fn healthz(&mut self) -> Result<(u16, String)> {
        self.status_json(FrameType::Health, FrameType::HealthResponse,
                         &[])
    }

    /// `GET /metrics` equivalent; returns `(status, JSON text)`.
    pub fn metrics(&mut self) -> Result<(u16, String)> {
        self.status_json(FrameType::Metrics, FrameType::MetricsResponse,
                         &[])
    }

    /// Model-lifecycle admin request — the wire twin of the HTTP
    /// `POST /v1/models/{name}:load|:unload|:setDefault` endpoints.
    /// `body` is the UTF-8 JSON request text, e.g.
    /// `{"action":"setDefault","name":"mlp","version":"v2"}` (for
    /// `load`, carry the loader spec inline or under a `spec` field).
    /// Returns `(status, JSON text)` exactly as HTTP would answer.
    pub fn admin(&mut self, body: &str) -> Result<(u16, String)> {
        self.status_json(FrameType::Admin, FrameType::AdminResponse,
                         body.as_bytes())
    }

    fn status_json(&mut self, req: FrameType, want: FrameType,
                   body: &[u8]) -> Result<(u16, String)> {
        write_frame(&mut self.writer, req, body)
            .with_context(|| format!("serve: send {req:?} frame"))?;
        let reply = read_frame(&mut self.reader)
            .map_err(|e| anyhow!("serve: read reply frame: {e}"))?;
        if reply.ty == want {
            return decode_status_json(&reply.body)
                .map_err(|e| anyhow!("serve: bad reply body: {e}"));
        }
        if reply.ty == FrameType::Error {
            let e = decode_error(&reply.body)
                .map_err(|e| anyhow!("serve: bad error body: {e}"))?;
            return Ok((e.status, e.message));
        }
        bail!("serve: unexpected reply frame {:?}", reply.ty)
    }
}
