//! Typed serving configuration: the single parse/validate path behind
//! `lutq serve`, `lutq route` and `lutq serve-bench`.
//!
//! The CLI surface of the serving subcommands grew flag by flag inside
//! `main.rs` until defaults, parsing and validation were copy-pasted
//! across three commands. This module owns all three surfaces as typed
//! structs — [`ServeConfig`], [`RouteConfig`], [`LoadConfig`] — each
//! with a `cli()` describing its flags, a `from_args()` that parses
//! *and validates* in one place, and unit tests pinning the rejection
//! of nonsense combinations (`--replicas 0`, a hedge threshold at or
//! below 1.0, arrival rates that are not positive, fault-injection
//! probabilities outside `[0, 1]`).
//!
//! Replica addressing is unified behind [`ReplicaSpec`]:
//! `host:port[@http|binary]` names both where a replica front lives and
//! how shard hops reach it, replacing the old comma-list plus
//! `--shard-transport` pairing. `lutq route`, `serve-bench` and the
//! smoke scripts all speak this one syntax.

use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use crate::cli::{Args, Cli};
use crate::infer::{ExecMode, KernelBackend};

use super::cluster::breaker::BreakerConfig;
use super::cluster::{HttpReplica, Replica, RouterConfig, WireReplica};
use super::load::Arrival;

/// How shard hops reach a remote replica front.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardTransport {
    /// JSON over the HTTP front, one request per sample
    Http,
    /// one batched frame per shard over the binary wire front
    Binary,
}

impl ShardTransport {
    pub fn tag(self) -> &'static str {
        match self {
            ShardTransport::Http => "http",
            ShardTransport::Binary => "binary",
        }
    }
}

/// One replica address plus its shard-hop transport, parsed from
/// `host:port[@http|binary]` (no suffix = the caller's default).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaSpec {
    pub addr: String,
    pub transport: ShardTransport,
}

impl ReplicaSpec {
    /// Parse one `host:port[@http|binary]` spec.
    pub fn parse(s: &str, default: ShardTransport) -> Result<ReplicaSpec> {
        let (addr, transport) = match s.split_once('@') {
            Some((a, t)) => (
                a,
                match t {
                    "http" => ShardTransport::Http,
                    "binary" => ShardTransport::Binary,
                    other => bail!(
                        "replica `{s}`: unknown transport `@{other}` \
                         (expected @http or @binary)"
                    ),
                },
            ),
            None => (s, default),
        };
        let addr = addr.trim();
        ensure!(!addr.is_empty(), "replica `{s}`: empty address");
        let Some((host, port)) = addr.rsplit_once(':') else {
            bail!("replica `{s}`: expected host:port[@http|binary]");
        };
        ensure!(!host.is_empty(), "replica `{s}`: empty host");
        ensure!(port.parse::<u16>().is_ok(),
                "replica `{s}`: `{port}` is not a port number");
        Ok(ReplicaSpec { addr: addr.to_string(), transport })
    }

    /// Parse a comma-separated spec list (blank entries skipped; at
    /// least one spec required).
    pub fn parse_list(s: &str,
                      default: ShardTransport) -> Result<Vec<ReplicaSpec>> {
        let specs = s
            .split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(|x| ReplicaSpec::parse(x, default))
            .collect::<Result<Vec<_>>>()?;
        ensure!(!specs.is_empty(), "no replica addresses given");
        Ok(specs)
    }

    /// The replica client this spec names.
    pub fn connect(&self) -> Box<dyn Replica> {
        match self.transport {
            ShardTransport::Http => Box::new(HttpReplica::new(&self.addr)),
            ShardTransport::Binary => {
                Box::new(WireReplica::new(&self.addr))
            }
        }
    }
}

/// Router tuning shared by every command that stands up a [`super::Router`]:
/// hedging, circuit-breaker backoff, and metrics-fed shard weighting.
/// `max_shard` stays per-command (route exposes it; serve/serve-bench
/// derive it from the batch cap).
#[derive(Debug, Clone, Copy)]
pub struct RouterKnobs {
    /// re-dispatch a shard when its elapsed time exceeds this multiple
    /// of the replica's expected time (0.0 = hedging off; must be
    /// > 1.0 otherwise — see [`RouterConfig`])
    pub hedge_threshold: f64,
    /// floor in ms under which a shard is never hedged
    pub hedge_min_ms: f64,
    /// circuit breaker: first backoff after a trip, in ms
    pub breaker_base_ms: f64,
    /// circuit breaker: backoff doubling cap, in ms
    pub breaker_max_ms: f64,
    /// weight shards by the replicas' own `/metrics` rows instead of
    /// router-side EWMAs only
    pub metrics_weights: bool,
}

impl RouterKnobs {
    /// Append the shared router flags to a command's CLI spec.
    pub fn cli(cli: Cli) -> Cli {
        cli.opt("hedge-threshold", "0",
                "hedge a shard when its elapsed time exceeds this \
                 multiple of the replica's expected time (0 = off; \
                 otherwise must be > 1.0)")
            .opt("hedge-min-ms", "1",
                 "never hedge a shard before this many ms elapsed")
            .opt("breaker-base-ms", "200",
                 "circuit breaker: first backoff after a replica trips")
            .opt("breaker-max-ms", "5000",
                 "circuit breaker: exponential backoff cap")
            .flag("metrics-weights",
                  "weight shards by the replicas' /metrics rows instead \
                   of router-side EWMAs only")
    }

    pub fn from_args(a: &Args) -> Result<RouterKnobs> {
        let k = RouterKnobs {
            hedge_threshold: a.get_f32("hedge-threshold") as f64,
            hedge_min_ms: a.get_f32("hedge-min-ms") as f64,
            breaker_base_ms: a.get_f32("breaker-base-ms") as f64,
            breaker_max_ms: a.get_f32("breaker-max-ms") as f64,
            metrics_weights: a.has_flag("metrics-weights"),
        };
        k.validate()?;
        Ok(k)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.hedge_threshold == 0.0 || self.hedge_threshold > 1.0,
            "--hedge-threshold must be 0 (off) or > 1.0 — a threshold \
             at or below 1.0 would hedge every shard before its own \
             expected completion (got {})",
            self.hedge_threshold
        );
        ensure!(self.hedge_min_ms >= 0.0,
                "--hedge-min-ms must be >= 0 (got {})", self.hedge_min_ms);
        ensure!(self.breaker_base_ms > 0.0,
                "--breaker-base-ms must be > 0 (got {})",
                self.breaker_base_ms);
        ensure!(
            self.breaker_max_ms >= self.breaker_base_ms,
            "--breaker-max-ms ({}) must be >= --breaker-base-ms ({})",
            self.breaker_max_ms, self.breaker_base_ms
        );
        Ok(())
    }

    /// The [`RouterConfig`] these knobs describe, at a given shard cap.
    pub fn router_config(&self, max_shard: usize) -> RouterConfig {
        RouterConfig {
            max_shard,
            hedge_threshold: self.hedge_threshold,
            hedge_min_ms: self.hedge_min_ms,
            breaker: BreakerConfig {
                base_ms: self.breaker_base_ms,
                max_ms: self.breaker_max_ms,
            },
            metrics_weights: self.metrics_weights,
        }
    }
}

impl Default for RouterKnobs {
    fn default() -> Self {
        RouterKnobs {
            hedge_threshold: 0.0,
            hedge_min_ms: 1.0,
            breaker_base_ms: 200.0,
            breaker_max_ms: 5000.0,
            metrics_weights: false,
        }
    }
}

/// Parse `--mode` (shared by every serving command).
pub fn parse_exec_mode(s: &str) -> Result<ExecMode> {
    Ok(match s {
        "dense" => ExecMode::Dense,
        "lut" => ExecMode::LutTrick,
        "shift" => ExecMode::ShiftOnly,
        m => bail!("unknown mode `{m}` (dense | lut | shift)"),
    })
}

/// Resolve a `0 = one per core` worker/thread count.
pub fn resolve_workers(workers: usize) -> usize {
    if workers == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        workers
    }
}

/// The `lutq serve` surface: HTTP (and optionally wire) fronts over a
/// compiled registry, with `replicas > 1` sharding through an
/// in-process cluster router.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifact: String,
    pub model: String,
    pub addr: String,
    /// empty = HTTP only
    pub wire_addr: String,
    pub mode: ExecMode,
    pub kernel: KernelBackend,
    pub batch: usize,
    /// 0 = one per core (see [`resolve_workers`])
    pub workers: usize,
    /// autoscaler floor per replica (meaningful when `max_workers > 0`)
    pub min_workers: usize,
    /// autoscaler ceiling per replica; 0 = fixed `--workers` pool
    pub max_workers: usize,
    pub plan_threads: usize,
    pub linger: Duration,
    pub queue_cap: usize,
    pub max_conns: usize,
    pub replicas: usize,
    pub max_seconds: u64,
    /// empty = no JSONL
    pub metrics_jsonl: String,
    /// assumed per-batch ms for cold models at admission (0 = legacy
    /// optimism; see [`super::Admission::with_prior`])
    pub admission_prior_ms: f64,
    pub knobs: RouterKnobs,
}

impl ServeConfig {
    pub fn cli() -> Cli {
        let cli = Cli::new("lutq serve",
                           "HTTP serving front over the coalescing Server")
            .req("artifact",
                 "artifact preset(s), comma-separated; `synthetic` serves \
                  two built-in models with no files")
            .opt("model", "",
                 "exported model file(s), comma-separated (matched 1:1 \
                  with --artifact)")
            .opt("addr", "127.0.0.1:8080",
                 "bind address (port 0 picks an ephemeral port)")
            .opt("wire-addr", "",
                 "also serve the binary framed wire protocol here \
                  (empty = HTTP only; port 0 picks an ephemeral port)")
            .opt("mode", "lut", "dense | lut | shift")
            .opt("kernel", "auto",
                 "auto | scalar | simd | int | int-scalar")
            .opt("batch", "8", "coalescing cap per batch")
            .opt("workers", "0",
                 "server worker threads (0 = one per core); ignored \
                  when --max-workers enables autoscaling")
            .opt("min-workers", "1",
                 "autoscaler floor: never shrink below this many \
                  workers per replica (needs --max-workers)")
            .opt("max-workers", "0",
                 "autoscale the worker pool between --min-workers and \
                  this ceiling from queue depth + service-time EWMAs \
                  (0 = fixed --workers pool)")
            .opt("plan-threads", "1",
                 "intra-plan threads per server worker")
            .opt("linger-ms", "1",
                 "max ms a partial batch waits to coalesce")
            .opt("queue-cap", "1024", "bounded per-model queue depth")
            .opt("max-conns", "256", "max concurrent http connections")
            .opt("replicas", "1",
                 "in-process replica servers behind a sharding router \
                  (>1 = cluster mode; workers are split across replicas)")
            .opt("max-seconds", "0",
                 "serve for N seconds, then drain and exit (0 = forever)")
            .opt("metrics-jsonl", "",
                 "write per-model serve_model JSONL rows here on shutdown \
                  (cluster mode adds serve_cluster/serve_replica rows)")
            .opt("admission-prior-ms", "0",
                 "assumed per-batch service time for models that have \
                  not executed a batch yet, so cold starts shed early \
                  instead of queueing blind (0 = admit everything)");
        RouterKnobs::cli(cli)
    }

    pub fn from_args(a: &Args) -> Result<ServeConfig> {
        let cfg = ServeConfig {
            artifact: a.get("artifact").to_string(),
            model: a.get("model").to_string(),
            addr: a.get("addr").to_string(),
            wire_addr: a.get("wire-addr").to_string(),
            mode: parse_exec_mode(a.get("mode"))?,
            kernel: a
                .get("kernel")
                .parse::<KernelBackend>()
                .map_err(|e| anyhow!("{e}"))?,
            batch: a.get_usize("batch"),
            workers: a.get_usize("workers"),
            min_workers: a.get_usize("min-workers"),
            max_workers: a.get_usize("max-workers"),
            plan_threads: a.get_usize("plan-threads").max(1),
            linger: Duration::from_millis(a.get_u64("linger-ms")),
            queue_cap: a.get_usize("queue-cap"),
            max_conns: a.get_usize("max-conns"),
            replicas: a.get_usize("replicas"),
            max_seconds: a.get_u64("max-seconds"),
            metrics_jsonl: a.get("metrics-jsonl").to_string(),
            admission_prior_ms: a.get_f32("admission-prior-ms") as f64,
            knobs: RouterKnobs::from_args(a)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.replicas >= 1,
                "serve: --replicas must be >= 1 (0 replicas cannot \
                 answer anything)");
        ensure!(self.batch >= 1, "serve: --batch must be >= 1");
        if self.max_workers > 0 {
            ensure!(self.min_workers >= 1,
                    "serve: --min-workers must be >= 1 when autoscaling \
                     (an empty pool could never answer anything)");
            ensure!(
                self.max_workers >= self.min_workers,
                "serve: --max-workers ({}) must be >= --min-workers ({})",
                self.max_workers, self.min_workers
            );
        }
        ensure!(self.queue_cap >= 1, "serve: --queue-cap must be >= 1");
        ensure!(self.max_conns >= 1, "serve: --max-conns must be >= 1");
        ensure!(
            self.admission_prior_ms.is_finite()
                && self.admission_prior_ms >= 0.0,
            "serve: --admission-prior-ms must be a finite ms value >= 0 \
             (got {})",
            self.admission_prior_ms
        );
        self.knobs.validate()
    }
}

/// The `lutq route` surface: a standalone sharding tier over remote
/// replica fronts named by [`ReplicaSpec`]s.
#[derive(Debug, Clone)]
pub struct RouteConfig {
    pub replicas: Vec<ReplicaSpec>,
    pub addr: String,
    /// empty = HTTP only
    pub wire_addr: String,
    pub max_shard: usize,
    pub max_conns: usize,
    /// 0 = only on demand
    pub health_every_ms: u64,
    pub max_seconds: u64,
    /// empty = no JSONL
    pub metrics_jsonl: String,
    pub knobs: RouterKnobs,
}

impl RouteConfig {
    pub fn cli() -> Cli {
        let cli = Cli::new("lutq route",
                           "sharding router over remote replica fronts")
            .req("replicas",
                 "comma-separated replica specs host:port[@http|binary] \
                  of running `lutq serve` fronts (@binary hops need the \
                  replica's --wire-addr port; default @http)")
            .opt("addr", "127.0.0.1:8080",
                 "bind address (port 0 picks an ephemeral port)")
            .opt("wire-addr", "",
                 "also serve the binary framed wire protocol here \
                  (empty = HTTP only; port 0 picks an ephemeral port)")
            .opt("max-shard", "8",
                 "max samples handed to one replica as a single shard")
            .opt("max-conns", "256", "max concurrent http connections")
            .opt("health-every-ms", "1000",
                 "re-probe replica health every N ms, honouring breaker \
                  backoff (0 = only on demand)")
            .opt("max-seconds", "0",
                 "route for N seconds, then exit (0 = forever)")
            .opt("metrics-jsonl", "",
                 "write serve_cluster/serve_replica JSONL rows on \
                  shutdown");
        RouterKnobs::cli(cli)
    }

    pub fn from_args(a: &Args) -> Result<RouteConfig> {
        let cfg = RouteConfig {
            replicas: ReplicaSpec::parse_list(a.get("replicas"),
                                              ShardTransport::Http)?,
            addr: a.get("addr").to_string(),
            wire_addr: a.get("wire-addr").to_string(),
            max_shard: a.get_usize("max-shard"),
            max_conns: a.get_usize("max-conns"),
            health_every_ms: a.get_u64("health-every-ms"),
            max_seconds: a.get_u64("max-seconds"),
            metrics_jsonl: a.get("metrics-jsonl").to_string(),
            knobs: RouterKnobs::from_args(a)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.replicas.is_empty(),
                "route: --replicas lists no addresses");
        ensure!(self.max_shard >= 1, "route: --max-shard must be >= 1");
        ensure!(self.max_conns >= 1, "route: --max-conns must be >= 1");
        self.knobs.validate()
    }

    pub fn router_config(&self) -> RouterConfig {
        self.knobs.router_config(self.max_shard)
    }
}

/// Which serving path `serve-bench` measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchTransport {
    Inproc,
    Http,
    Binary,
    Cluster,
}

impl BenchTransport {
    fn parse(s: &str) -> Result<BenchTransport> {
        Ok(match s {
            "inproc" => BenchTransport::Inproc,
            "http" => BenchTransport::Http,
            "binary" => BenchTransport::Binary,
            "cluster" => BenchTransport::Cluster,
            other => bail!("unknown --transport `{other}` (inproc | \
                            http | binary | cluster)"),
        })
    }
}

/// How `serve-bench --transport cluster` fronts its own replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHop {
    Inproc,
    Http,
    Binary,
}

impl ShardHop {
    fn parse(s: &str) -> Result<ShardHop> {
        Ok(match s {
            "inproc" => ShardHop::Inproc,
            "http" => ShardHop::Http,
            "binary" => ShardHop::Binary,
            other => bail!("unknown --shard-transport `{other}` \
                            (inproc | http | binary)"),
        })
    }

    /// `(label suffix, transport field)` for cluster bench rows.
    pub fn row_tags(self) -> (&'static str, &'static str) {
        match self {
            ShardHop::Http => ("-http", "cluster-http"),
            ShardHop::Binary => ("-binary", "cluster-binary"),
            ShardHop::Inproc => ("", "cluster"),
        }
    }
}

/// Open-loop generator settings (one run per entry of `arrivals`).
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// one schedule per offered rate (a trace yields exactly one)
    pub arrivals: Vec<Arrival>,
    /// requests issued per schedule
    pub requests: usize,
    /// latency-under-SLO deadline bounds in ms
    pub slo_ms: Vec<f32>,
    /// schedule seed (same seed -> same send times)
    pub seed: u64,
    /// submitter threads sharing the schedule
    pub workers: usize,
}

/// Fault injection for the open-loop cluster leg: wrap one replica in a
/// `testkit::flaky`-style fault plan. Held as raw numbers so the config
/// layer stays decoupled from testkit; `main` builds the actual plan.
#[derive(Debug, Clone, Copy)]
pub struct FlakyKnobs {
    /// replica index to wrap
    pub replica: usize,
    pub drop_p: f32,
    pub error_p: f32,
    pub delay_p: f32,
    pub delay_ms: u64,
    pub seed: u64,
}

/// The `lutq serve-bench` surface.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub artifact: String,
    pub model: String,
    pub mode: ExecMode,
    pub kernel: KernelBackend,
    pub batch: usize,
    pub iters: usize,
    pub warmup: usize,
    /// direct-path plan threads (0 = one per core)
    pub threads: usize,
    /// server worker threads (0 = one per core)
    pub workers: usize,
    pub plan_threads: usize,
    pub linger: Duration,
    /// closed-loop client threads (0 = derived from workers/batch)
    pub clients: usize,
    pub transport: BenchTransport,
    pub replicas: usize,
    pub shard_hop: ShardHop,
    pub addr: String,
    pub wire_addr: String,
    pub deadline_ms: Option<f64>,
    /// empty = no JSON file
    pub json: String,
    pub compile_per_call: bool,
    pub no_serve: bool,
    /// `Some` switches the bench to open-loop latency-under-SLO rows
    pub open_loop: Option<OpenLoopConfig>,
    /// `Some` wraps one cluster replica in injected faults
    pub flaky: Option<FlakyKnobs>,
    pub knobs: RouterKnobs,
}

impl LoadConfig {
    pub fn cli() -> Cli {
        let cli = Cli::new("lutq serve-bench",
                           "serving benchmark: direct plan loop vs the \
                            coalescing Server path")
            .req("artifact",
                 "artifact preset(s), comma-separated; `synthetic` \
                  benches two built-in models with no files")
            .opt("model", "",
                 "exported model file(s), comma-separated (matched 1:1 \
                  with --artifact)")
            .opt("mode", "lut", "dense | lut | shift")
            .opt("kernel", "auto",
                 "kernel backend: auto | scalar | simd | int | \
                  int-scalar (auto honours the LUTQ_KERNEL env \
                  override) — A/B the backend seam")
            .opt("batch", "8",
                 "direct-path batch size, also the server coalescing cap")
            .opt("iters", "200",
                 "direct iterations per model; the server path answers \
                  iters*batch single-image requests per model")
            .opt("warmup", "20",
                 "warmup iterations (provision the arenas)")
            .opt("threads", "0",
                 "direct-path plan threads (0 = one per core)")
            .opt("workers", "0",
                 "server worker threads (0 = one per core)")
            .opt("plan-threads", "1",
                 "intra-plan threads per server worker")
            .opt("linger-ms", "1",
                 "server: max ms a partial batch waits to coalesce")
            .opt("clients", "0",
                 "closed-loop client threads (0 = max(2x workers, \
                  2x batch) so coalesced batches can fill)")
            .opt("transport", "inproc",
                 "serving path to bench: inproc (submit/wait \
                  in-process), http (adds full-network-path rows \
                  through an HttpFront), binary (http rows plus \
                  wire-protocol rows through a WireServer) or cluster \
                  (1-vs-N replica scaling rows through the sharding \
                  Router)")
            .opt("replicas", "3",
                 "cluster transport: replica servers behind the router \
                  (the bench runs both 1 and N for the scaling \
                  comparison)")
            .opt("shard-transport", "inproc",
                 "cluster transport: how the router reaches its \
                  replicas: inproc | http (per-replica HttpFront) | \
                  binary (per-replica WireServer, one batched frame per \
                  shard)")
            .opt("addr", "127.0.0.1:0",
                 "http transport: bind address (port 0 = ephemeral)")
            .opt("wire-addr", "127.0.0.1:0",
                 "binary transport: wire bind address (port 0 = \
                  ephemeral)")
            .opt("deadline-ms", "0",
                 "http/binary/cluster/open-loop: client deadline per \
                  request; 0 = none (429 sheds land in the shed-rate \
                  and SLO rows)")
            .opt("json", "", "also write the rows to this JSON file")
            .flag("compile-per-call",
                  "add the legacy re-lower-per-request comparison row")
            .flag("no-serve", "direct rows only (skip the Server path)")
            .opt("arrival", "",
                 "open-loop arrival schedule: poisson | bursty | trace \
                  (empty = closed-loop bench only)")
            .opt("rate", "200",
                 "open-loop offered rate(s) in req/s, comma-separated \
                  sweep (ignored by --arrival trace)")
            .opt("open-requests", "400",
                 "open-loop requests issued per offered rate")
            .opt("slo-ms", "5,10,25,50,100",
                 "latency-under-SLO deadline bounds in ms, \
                  comma-separated")
            .opt("burst", "32",
                 "bursty arrival: requests per hot/cold phase")
            .opt("burst-factor", "4",
                 "bursty arrival: hot phase runs at rate*factor, cold \
                  at rate/factor")
            .opt("trace", "",
                 "trace arrival: file of inter-arrival gaps in ms (one \
                  per line, # comments)")
            .opt("open-seed", "0", "open-loop schedule seed")
            .opt("open-workers", "64",
                 "open-loop submitter threads sharing the schedule")
            .opt("flaky-replica", "",
                 "cluster transport: inject faults into this replica \
                  index (empty = none)")
            .opt("flaky-drop-p", "0",
                 "injected probability a shard hop is silently dropped \
                  (the router sees a transport-style loss)")
            .opt("flaky-error-p", "0",
                 "injected probability a shard hop fails outright")
            .opt("flaky-delay-p", "0",
                 "injected probability a shard hop is delayed")
            .opt("flaky-delay-ms", "10", "injected delay length in ms")
            .opt("flaky-seed", "7", "fault plan seed");
        RouterKnobs::cli(cli)
    }

    pub fn from_args(a: &Args) -> Result<LoadConfig> {
        let deadline_ms = match a.get_f32("deadline-ms") as f64 {
            v if v > 0.0 => Some(v),
            _ => None,
        };
        let open_loop = if a.get("arrival").is_empty() {
            None
        } else {
            let kind = a.get("arrival");
            let arrivals = if kind == "trace" {
                let path = a.get("trace");
                ensure!(!path.is_empty(),
                        "--arrival trace needs --trace <file>");
                vec![Arrival::from_trace_file(path)?]
            } else {
                let burst = a.get_usize("burst");
                let factor = a.get_f32("burst-factor") as f64;
                parse_f64_list(a.get("rate"), "--rate")?
                    .into_iter()
                    .map(|rps| Arrival::parse(kind, rps, burst, factor))
                    .collect::<Result<Vec<_>>>()?
            };
            Some(OpenLoopConfig {
                arrivals,
                requests: a.get_usize("open-requests"),
                slo_ms: parse_f64_list(a.get("slo-ms"), "--slo-ms")?
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
                seed: a.get_u64("open-seed"),
                workers: a.get_usize("open-workers").max(1),
            })
        };
        let flaky = if a.get("flaky-replica").is_empty() {
            None
        } else {
            Some(FlakyKnobs {
                replica: a.get_usize("flaky-replica"),
                drop_p: a.get_f32("flaky-drop-p"),
                error_p: a.get_f32("flaky-error-p"),
                delay_p: a.get_f32("flaky-delay-p"),
                delay_ms: a.get_u64("flaky-delay-ms"),
                seed: a.get_u64("flaky-seed"),
            })
        };
        let cfg = LoadConfig {
            artifact: a.get("artifact").to_string(),
            model: a.get("model").to_string(),
            mode: parse_exec_mode(a.get("mode"))?,
            kernel: a
                .get("kernel")
                .parse::<KernelBackend>()
                .map_err(|e| anyhow!("{e}"))?,
            batch: a.get_usize("batch"),
            iters: a.get_usize("iters"),
            warmup: a.get_usize("warmup"),
            threads: a.get_usize("threads"),
            workers: a.get_usize("workers"),
            plan_threads: a.get_usize("plan-threads").max(1),
            linger: Duration::from_millis(a.get_u64("linger-ms")),
            clients: a.get_usize("clients"),
            transport: BenchTransport::parse(a.get("transport"))?,
            replicas: a.get_usize("replicas"),
            shard_hop: ShardHop::parse(a.get("shard-transport"))?,
            addr: a.get("addr").to_string(),
            wire_addr: a.get("wire-addr").to_string(),
            deadline_ms,
            json: a.get("json").to_string(),
            compile_per_call: a.has_flag("compile-per-call"),
            no_serve: a.has_flag("no-serve"),
            open_loop,
            flaky,
            knobs: RouterKnobs::from_args(a)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.batch >= 1, "serve-bench: --batch must be >= 1");
        ensure!(self.iters >= 1, "serve-bench: --iters must be >= 1");
        ensure!(self.replicas >= 1,
                "serve-bench: --replicas must be >= 1 (0 replicas \
                 cannot answer anything)");
        ensure!(self.transport == BenchTransport::Inproc || !self.no_serve,
                "--transport needs the server path (drop --no-serve)");
        if let Some(ol) = &self.open_loop {
            ensure!(
                matches!(self.transport,
                         BenchTransport::Inproc | BenchTransport::Cluster),
                "open-loop load (--arrival) supports --transport inproc \
                 or cluster"
            );
            ensure!(ol.requests >= 1,
                    "--open-requests must be >= 1");
            ensure!(!ol.slo_ms.is_empty(),
                    "--slo-ms lists no deadline bounds");
            ensure!(ol.slo_ms.iter().all(|b| b.is_finite() && *b > 0.0),
                    "--slo-ms bounds must be positive ms values");
        }
        if let Some(f) = &self.flaky {
            ensure!(self.transport == BenchTransport::Cluster,
                    "--flaky-replica needs --transport cluster");
            ensure!(f.replica < self.replicas,
                    "--flaky-replica {} out of range (replicas: {})",
                    f.replica, self.replicas);
            for (name, p) in [("--flaky-drop-p", f.drop_p),
                              ("--flaky-error-p", f.error_p),
                              ("--flaky-delay-p", f.delay_p)] {
                ensure!((0.0..=1.0).contains(&p),
                        "{name} must be a probability in [0, 1] \
                         (got {p})");
            }
        }
        self.knobs.validate()
    }
}

fn parse_f64_list(s: &str, flag: &str) -> Result<Vec<f64>> {
    let vals = s
        .split(',')
        .map(str::trim)
        .filter(|x| !x.is_empty())
        .map(|x| {
            x.parse::<f64>()
                .map_err(|_| anyhow!("{flag}: `{x}` is not a number"))
        })
        .collect::<Result<Vec<f64>>>()?;
    ensure!(!vals.is_empty(), "{flag} lists no values");
    Ok(vals)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn replica_spec_parses_transport_suffix() {
        let s = ReplicaSpec::parse("127.0.0.1:9001@binary",
                                   ShardTransport::Http)
            .unwrap();
        assert_eq!(s.addr, "127.0.0.1:9001");
        assert_eq!(s.transport, ShardTransport::Binary);
        let s = ReplicaSpec::parse("10.0.0.2:80",
                                   ShardTransport::Http)
            .unwrap();
        assert_eq!(s.transport, ShardTransport::Http);
        assert!(ReplicaSpec::parse("h:1@carrier-pigeon",
                                   ShardTransport::Http)
            .is_err());
        assert!(ReplicaSpec::parse("no-port@http", ShardTransport::Http)
            .is_err());
        assert!(ReplicaSpec::parse("h:not-a-port", ShardTransport::Http)
            .is_err());
        assert!(ReplicaSpec::parse(":8080", ShardTransport::Http)
            .is_err());
    }

    #[test]
    fn replica_spec_list_trims_and_rejects_empty() {
        let l = ReplicaSpec::parse_list(
            " 127.0.0.1:1@http , 127.0.0.1:2@binary ,",
            ShardTransport::Http,
        )
        .unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!(l[1].transport, ShardTransport::Binary);
        assert!(ReplicaSpec::parse_list(" , ", ShardTransport::Http)
            .is_err());
    }

    #[test]
    fn serve_config_rejects_zero_replicas_and_bad_hedge() {
        let parse = |extra: &[&str]| {
            let mut t = toks(&["--artifact", "synthetic"]);
            t.extend(toks(extra));
            let a = ServeConfig::cli().parse_from(&t).unwrap();
            ServeConfig::from_args(&a)
        };
        assert!(parse(&[]).is_ok());
        assert!(parse(&["--replicas", "0"]).is_err());
        assert!(parse(&["--hedge-threshold", "0.9"]).is_err());
        assert!(parse(&["--hedge-threshold", "1.0"]).is_err());
        assert!(parse(&["--hedge-threshold", "0"]).is_ok());
        let cfg = parse(&["--replicas", "3", "--hedge-threshold", "3.0",
                          "--metrics-weights"])
            .unwrap();
        assert_eq!(cfg.replicas, 3);
        let rc = cfg.knobs.router_config(cfg.batch);
        assert_eq!(rc.hedge_threshold, 3.0);
        assert!(rc.metrics_weights);
        assert!(parse(&["--breaker-base-ms", "0"]).is_err());
        assert!(parse(&["--breaker-base-ms", "500", "--breaker-max-ms",
                        "100"])
            .is_err());
        assert!(parse(&["--admission-prior-ms", "-5"]).is_err());
    }

    #[test]
    fn serve_config_validates_autoscale_bounds() {
        let parse = |extra: &[&str]| {
            let mut t = toks(&["--artifact", "synthetic"]);
            t.extend(toks(extra));
            let a = ServeConfig::cli().parse_from(&t).unwrap();
            ServeConfig::from_args(&a)
        };
        // autoscaling off by default: fixed pool, no bound checks
        let cfg = parse(&[]).unwrap();
        assert_eq!(cfg.max_workers, 0);
        assert_eq!(cfg.min_workers, 1);
        let cfg = parse(&["--min-workers", "2", "--max-workers", "6"])
            .unwrap();
        assert_eq!((cfg.min_workers, cfg.max_workers), (2, 6));
        assert!(parse(&["--max-workers", "4", "--min-workers", "0"])
            .is_err());
        assert!(parse(&["--max-workers", "2", "--min-workers", "5"])
            .is_err());
        // a nonsense floor without a ceiling stays inert (fixed pool)
        assert!(parse(&["--min-workers", "0"]).is_ok());
    }

    #[test]
    fn route_config_parses_mixed_replica_specs() {
        let t = toks(&["--replicas",
                       "127.0.0.1:9001,127.0.0.1:9002@binary",
                       "--max-shard", "4"]);
        let a = RouteConfig::cli().parse_from(&t).unwrap();
        let cfg = RouteConfig::from_args(&a).unwrap();
        assert_eq!(cfg.replicas.len(), 2);
        assert_eq!(cfg.replicas[0].transport, ShardTransport::Http);
        assert_eq!(cfg.replicas[1].transport, ShardTransport::Binary);
        assert_eq!(cfg.router_config().max_shard, 4);
        let t = toks(&["--replicas", "127.0.0.1:9001", "--max-shard",
                       "0"]);
        let a = RouteConfig::cli().parse_from(&t).unwrap();
        assert!(RouteConfig::from_args(&a).is_err());
    }

    #[test]
    fn load_config_builds_open_loop_sweep() {
        let t = toks(&["--artifact", "synthetic", "--transport",
                       "cluster", "--arrival", "poisson", "--rate",
                       "100,250", "--open-requests", "50", "--slo-ms",
                       "5,25"]);
        let a = LoadConfig::cli().parse_from(&t).unwrap();
        let cfg = LoadConfig::from_args(&a).unwrap();
        let ol = cfg.open_loop.as_ref().unwrap();
        assert_eq!(ol.arrivals.len(), 2);
        assert_eq!(ol.arrivals[0].tag(), "poisson");
        assert_eq!(ol.requests, 50);
        assert_eq!(ol.slo_ms, vec![5.0, 25.0]);
        // closed-loop when --arrival is absent
        let t = toks(&["--artifact", "synthetic"]);
        let a = LoadConfig::cli().parse_from(&t).unwrap();
        assert!(LoadConfig::from_args(&a).unwrap().open_loop.is_none());
    }

    #[test]
    fn load_config_rejects_nonsense() {
        let parse = |extra: &[&str]| {
            let mut t = toks(&["--artifact", "synthetic"]);
            t.extend(toks(extra));
            let a = LoadConfig::cli().parse_from(&t).unwrap();
            LoadConfig::from_args(&a)
        };
        assert!(parse(&["--arrival", "uniform"]).is_err());
        assert!(parse(&["--arrival", "poisson", "--rate", "0"]).is_err());
        assert!(parse(&["--arrival", "poisson", "--transport", "http"])
            .is_err());
        assert!(parse(&["--arrival", "trace"]).is_err());
        assert!(parse(&["--transport", "cluster", "--flaky-replica",
                        "5", "--replicas", "3"])
            .is_err());
        assert!(parse(&["--transport", "cluster", "--flaky-replica",
                        "0", "--flaky-drop-p", "1.5"])
            .is_err());
        assert!(parse(&["--flaky-replica", "0"]).is_err(),
                "flaky injection needs the cluster transport");
        let cfg = parse(&["--transport", "cluster", "--flaky-replica",
                          "1", "--flaky-drop-p", "0.1",
                          "--flaky-delay-p", "0.3", "--flaky-delay-ms",
                          "15"])
            .unwrap();
        let f = cfg.flaky.unwrap();
        assert_eq!(f.replica, 1);
        assert_eq!(f.delay_ms, 15);
    }

    #[test]
    fn shard_hop_tags_match_row_label_convention() {
        assert_eq!(ShardHop::Inproc.row_tags(), ("", "cluster"));
        assert_eq!(ShardHop::Http.row_tags(), ("-http", "cluster-http"));
        assert_eq!(ShardHop::Binary.row_tags(),
                   ("-binary", "cluster-binary"));
        assert!(ShardHop::parse("telepathy").is_err());
        assert!(BenchTransport::parse("telepathy").is_err());
    }
}
