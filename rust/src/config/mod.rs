//! Run configuration for the Rust coordinator.
//!
//! Model/quantization structure lives in the *artifact* (baked at AOT
//! time, echoed in its manifest); this config covers everything the L3
//! trainer decides at run time: which artifact, how many steps, schedules
//! (learning rate, pruning fraction, INQ freeze fraction), dataset sizes,
//! eval cadence, checkpointing.

use crate::coordinator::schedule::LrSchedule;
use crate::quant::inq::InqSchedule;
use crate::quant::pruning::PruneSchedule;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// SyntheticImages::cifar — 10-class CIFAR stand-in
    Cifar,
    /// SyntheticImages::imagenet — 20-class ImageNet stand-in
    ImageNet,
    /// SyntheticShapes — VOC detection stand-in
    Detect,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifact: String,
    pub dataset: DatasetKind,
    pub train_len: usize,
    pub eval_len: usize,
    pub steps: usize,
    pub seed: u64,
    pub lr: LrSchedule,
    /// pruning-fraction schedule (pfrac artifact input); None -> 0.0
    pub prune: Option<PruneSchedule>,
    /// INQ freeze schedule (aux artifact input); None -> 0.0
    pub inq: Option<InqSchedule>,
    pub eval_every: usize,
    pub log_every: usize,
    pub checkpoint_every: usize,
    pub checkpoint_dir: Option<std::path::PathBuf>,
    pub keep_checkpoints: usize,
    /// prefetch worker threads (0 = synchronous batching)
    pub workers: usize,
    pub augment: bool,
}

impl TrainConfig {
    pub fn new(artifact: &str) -> Self {
        let dataset = if artifact.starts_with("imnet") {
            DatasetKind::ImageNet
        } else if artifact.starts_with("voc") {
            DatasetKind::Detect
        } else {
            DatasetKind::Cifar
        };
        // the unbounded-coordinate YOLO loss diverges above ~0.01 when the
        // warmup is short; 0.005 is stable across seeds
        let peak_lr =
            if dataset == DatasetKind::Detect { 0.005 } else { 0.05 };
        TrainConfig {
            artifact: artifact.to_string(),
            dataset,
            train_len: 4096,
            eval_len: 1024,
            steps: 300,
            seed: 0,
            lr: LrSchedule::cosine(peak_lr, 300, 20),
            prune: None,
            inq: None,
            eval_every: 0,
            log_every: 25,
            checkpoint_every: 0,
            checkpoint_dir: None,
            keep_checkpoints: 2,
            workers: 2,
            augment: true,
        }
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self.lr = self.lr.rescaled(steps);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn lr(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    pub fn prune(mut self, target: f32) -> Self {
        self.prune = Some(PruneSchedule {
            target,
            ramp_steps: self.steps / 3,
            warmup: self.steps / 10,
        });
        self
    }

    pub fn inq_standard(mut self) -> Self {
        self.inq = Some(InqSchedule::standard(self.steps));
        self
    }

    pub fn eval_every(mut self, n: usize) -> Self {
        self.eval_every = n;
        self
    }

    pub fn data_lens(mut self, train: usize, eval: usize) -> Self {
        self.train_len = train;
        self.eval_len = eval;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_picks_dataset() {
        assert_eq!(TrainConfig::new("cifar_lutq4").dataset,
                   DatasetKind::Cifar);
        assert_eq!(TrainConfig::new("imnet_s_fp32").dataset,
                   DatasetKind::ImageNet);
        assert_eq!(TrainConfig::new("voc_lutq4").dataset,
                   DatasetKind::Detect);
    }

    #[test]
    fn builders_compose() {
        let c = TrainConfig::new("cifar_lutq2")
            .steps(100)
            .seed(7)
            .prune(0.7)
            .eval_every(50);
        assert_eq!(c.steps, 100);
        assert_eq!(c.seed, 7);
        assert_eq!(c.prune.unwrap().target, 0.7);
        assert_eq!(c.eval_every, 50);
    }
}
