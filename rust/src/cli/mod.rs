//! Declarative command-line parsing (offline substitute for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! auto-generated `--help`. Used by the `lutq` binary, the examples and the
//! bench harnesses.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

pub struct Cli {
    name: &'static str,
    about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, specs: Vec::new() }
    }

    /// `--key <value>` option with default.
    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Required `--key <value>` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    /// Boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_flag) {
                (_, true) => "[flag]".to_string(),
                (Some(d), _) => format!("[default: {d}]"),
                (None, _) => "[required]".to_string(),
            };
            s.push_str(&format!("  --{:<18} {} {}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse from an explicit token list (testable) — returns Err(usage) on
    /// `--help` or malformed input.
    pub fn parse_from(&self, tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}",
                                           self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        // defaults + required check
        for spec in &self.specs {
            if spec.is_flag {
                continue;
            }
            if !args.values.contains_key(spec.name) {
                match &spec.default {
                    Some(d) => {
                        args.values.insert(spec.name.to_string(), d.clone());
                    }
                    None => {
                        return Err(format!("missing required --{}\n\n{}",
                                           spec.name, self.usage()))
                    }
                }
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` (skipping argv[0]); exits on --help/err.
    pub fn parse(&self) -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&tokens) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .unwrap_or_else(|| panic!("no such option --{key}"))
    }

    pub fn get_usize(&self, key: &str) -> usize {
        self.get(key)
            .parse()
            .unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn get_u64(&self, key: &str) -> u64 {
        self.get(key)
            .parse()
            .unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn get_f32(&self, key: &str) -> f32 {
        self.get(key)
            .parse()
            .unwrap_or_else(|_| panic!("--{key} must be a float"))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("steps", "100", "number of steps")
            .req("preset", "artifact preset")
            .flag("verbose", "chatty")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_opts_flags_positional() {
        let a = cli()
            .parse_from(&toks(&[
                "--preset", "cifar", "--steps=7", "--verbose", "pos1",
            ]))
            .unwrap();
        assert_eq!(a.get("preset"), "cifar");
        assert_eq!(a.get_usize("steps"), 7);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn applies_defaults() {
        let a = cli().parse_from(&toks(&["--preset", "x"])).unwrap();
        assert_eq!(a.get_usize("steps"), 100);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(&toks(&[])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse_from(&toks(&["--nope", "1"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse_from(&toks(&["--help"])).unwrap_err();
        assert!(err.contains("--steps"));
    }
}
