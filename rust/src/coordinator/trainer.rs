//! The training orchestrator: drives the AOT train_step artifact over the
//! data pipeline with L3-owned schedules (learning rate, pruning fraction,
//! INQ freeze fraction), periodic evaluation, metrics and checkpoints.
//!
//! The entire LUT-Q per-minibatch algorithm (paper Table 1) executes
//! *inside* the artifact; Rust owns everything around it.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{DatasetKind, TrainConfig};
use crate::data::{Batch, Dataset, Prefetcher, SyntheticImages,
                  SyntheticShapes};
use crate::info;
use crate::params::ParamStore;
use crate::runtime::{self, Manifest, Program, Runtime};
use crate::util::Timer;

use super::metrics::Metrics;

pub struct TrainResult {
    pub final_loss: f32,
    pub eval_error: f32,
    pub eval_loss: f32,
    pub loss_history: Vec<(usize, f32)>,
    pub state: ParamStore,
    pub steps_per_sec: f64,
    pub manifest: Manifest,
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub cfg: TrainConfig,
    pub manifest: Manifest,
    train_prog: Arc<Program>,
    eval_prog: Arc<Program>,
    init_prog: Arc<Program>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: TrainConfig) -> Result<Self> {
        let manifest = rt.manifest(&cfg.artifact)?;
        let train_prog = rt.load_program(&manifest, "train_step")?;
        let eval_prog = rt.load_program(&manifest, "eval_step")?;
        let init_prog = rt.load_program(&manifest, "init")?;
        Ok(Trainer { rt, cfg, manifest, train_prog, eval_prog, init_prog })
    }

    /// Train and eval are disjoint index windows over ONE dataset: they
    /// share the generative world (class prototypes derive from the seed)
    /// but never the same examples.
    pub fn train_dataset(&self) -> Arc<dyn Dataset> {
        let full = self.make_dataset(self.cfg.train_len + self.cfg.eval_len,
                                     self.cfg.seed, self.cfg.augment);
        Arc::new(crate::data::Slice::new(full, 0, self.cfg.train_len))
    }

    pub fn eval_dataset(&self) -> Arc<dyn Dataset> {
        // augmentation off for eval
        let full = self.make_dataset(self.cfg.train_len + self.cfg.eval_len,
                                     self.cfg.seed, false);
        Arc::new(crate::data::Slice::new(full, self.cfg.train_len,
                                         self.cfg.eval_len))
    }

    /// Index offset of the eval window within the shared dataset (for
    /// ground-truth lookups by detection harnesses).
    pub fn eval_offset(&self) -> usize {
        self.cfg.train_len
    }

    fn make_dataset(&self, len: usize, seed: u64,
                    augment: bool) -> Arc<dyn Dataset> {
        let m = &self.manifest.meta;
        if m.input.len() == 1 {
            // MLP artifact: flat-vector classification task
            return Arc::new(crate::data::FlatVectors::new(
                m.input[0], m.num_classes, len, seed, 0.8,
            ));
        }
        // Noise levels chosen so the fp32 reference lands at a CIFAR-like
        // error (~6-12%): hard enough that low-bit quantization measurably
        // degrades accuracy (the paper's regime), easy enough to train in
        // a few hundred CPU steps.
        match self.cfg.dataset {
            DatasetKind::Cifar => Arc::new(
                SyntheticImages::new(m.input[0], *m.input.get(2).unwrap_or(&3),
                                     m.num_classes, len, seed, 1.6)
                    .with_augment(augment),
            ),
            DatasetKind::ImageNet => Arc::new(
                SyntheticImages::new(m.input[0], *m.input.get(2).unwrap_or(&3),
                                     m.num_classes, len, seed, 1.9)
                    .with_augment(augment),
            ),
            DatasetKind::Detect => Arc::new(SyntheticShapes::with_dims(
                len, seed, m.input[0], m.grid, m.num_classes,
            )),
        }
    }

    /// Initialize state on device via the init artifact.
    pub fn init_state(&self) -> Result<Vec<xla::Literal>> {
        runtime::executable::run_init(&self.init_prog, self.cfg.seed as i32)
    }

    fn batch_literals(&self, batch: &Batch)
                      -> Result<(xla::Literal, xla::Literal)> {
        let spec = &self.train_prog.spec;
        let x = runtime::literal_f32(&spec.inputs[0].shape, &batch.x)?;
        let t = runtime::literal_f32(&spec.inputs[1].shape, &batch.t)?;
        Ok((x, t))
    }

    /// One train step: reads the state literals (by reference — no host
    /// copies) and returns the loss plus the updated state.
    pub fn step(&self, step_idx: usize, batch: &Batch,
                state: &[xla::Literal]) -> Result<(f32, Vec<xla::Literal>)> {
        let (x, t) = self.batch_literals(batch)?;
        let lr = self.cfg.lr.at(step_idx);
        let aux = self.cfg.inq.as_ref().map_or(0.0, |s| s.frac_at(step_idx));
        let pfrac = self.cfg.prune.as_ref().map_or(0.0, |s| s.at(step_idx));
        let scalars =
            [runtime::scalar_f32(lr), runtime::scalar_f32(aux),
             runtime::scalar_f32(pfrac)];
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(5 + state.len());
        args.push(&x);
        args.push(&t);
        args.extend(scalars.iter());
        args.extend(state.iter());
        let out = self.train_prog.run(&args).context("train_step")?;
        let (head, tail) = out.split_off(1);
        let loss = head[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?;
        Ok((loss, tail))
    }

    /// Full evaluation pass: returns (mean_loss, error_rate).
    /// For detection heads error_rate is NaN (mAP is computed separately by
    /// the detection harness via the infer program).
    pub fn evaluate(&self, state: &[xla::Literal]) -> Result<(f32, f32)> {
        let ds = self.eval_dataset();
        let spec = &self.eval_prog.spec;
        let batch_size = spec.inputs[0].shape[0];
        let batches =
            crate::data::Batcher::eval_batches(ds.as_ref(), batch_size);
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut total = 0usize;
        for (batch, valid) in &batches {
            // Padded tail examples repeat a valid example; to keep the
            // counts exact we evaluate them but scale by valid/batch_size.
            let x = runtime::literal_f32(&spec.inputs[0].shape, &batch.x)?;
            let t = runtime::literal_f32(&spec.inputs[1].shape, &batch.t)?;
            // state is passed BY REFERENCE (execute accepts Borrow<Literal>)
            // so evaluation never copies the model host-side (§Perf).
            let mut args: Vec<&xla::Literal> =
                Vec::with_capacity(2 + state.len());
            args.push(&x);
            args.push(&t);
            args.extend(state.iter());
            let out = self.eval_prog.run(&args).context("eval_step")?;
            let l = out.f32_scalar(0)?;
            let c = out.f32_scalar(1)?;
            let frac = *valid as f64 / batch_size as f64;
            loss_sum += l as f64 * frac;
            correct += c as f64 * frac;
            total += valid;
        }
        let mean_loss = (loss_sum / total as f64) as f32;
        let error_rate = if self.manifest.meta.head == "classify" {
            1.0 - (correct / total as f64) as f32
        } else {
            f32::NAN
        };
        Ok((mean_loss, error_rate))
    }

    /// Run the full training loop.
    pub fn run(&self) -> Result<TrainResult> {
        let mut metrics = Metrics::new(
            self.cfg
                .checkpoint_dir
                .as_ref()
                .map(|d| d.join(format!("{}.jsonl", self.cfg.artifact)))
                .as_deref(),
        )?;
        info!(
            "train {}: {} steps, {} params, method={} bits={}",
            self.cfg.artifact,
            self.cfg.steps,
            self.manifest.param_count(),
            self.manifest.quant_method(),
            self.manifest.quant_bits()
        );

        let mut state = self.init_state()?;
        let ds = self.train_dataset();

        let run_t = Timer::start();
        let mut final_loss = f32::NAN;

        // Prefetched pipeline when workers > 0, else synchronous.
        let mut prefetcher = if self.cfg.workers > 0 {
            Some(self.make_prefetcher(ds.clone()))
        } else {
            None
        };
        let mut sync_batcher = if self.cfg.workers == 0 {
            Some(crate::data::Batcher::new(ds.as_ref(),
                                           self.manifest.batch_size,
                                           self.cfg.seed, true))
        } else {
            None
        };

        for step in 0..self.cfg.steps {
            let batch = match (&mut prefetcher, &mut sync_batcher) {
                (Some(p), _) => p.next_batch(),
                (_, Some(b)) => b.next_batch(),
                _ => unreachable!(),
            };
            let t = Timer::start();
            let (loss, new_state) = self.step(step, &batch, &state)?;
            state = new_state;
            let ms = t.elapsed_ms();
            final_loss = loss;
            metrics.record_step(step, loss, self.cfg.lr.at(step), ms)?;
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                info!(
                    "  step {step:>5} loss {loss:>8.4} lr {:.4} ({ms:.0} ms)",
                    self.cfg.lr.at(step)
                );
            }
            if self.cfg.eval_every > 0
                && step > 0
                && step % self.cfg.eval_every == 0
            {
                let (el, er) = self.evaluate(&state)?;
                metrics.record_eval(step, el, er)?;
                info!("  eval @ {step}: loss {el:.4} err {:.2}%", er * 100.0);
            }
            if self.cfg.checkpoint_every > 0
                && step > 0
                && step % self.cfg.checkpoint_every == 0
            {
                self.checkpoint(&state, step as u64)?;
            }
        }
        let steps_per_sec = self.cfg.steps as f64 / run_t.elapsed_s();

        let (eval_loss, eval_error) = self.evaluate(&state)?;
        metrics.record_eval(self.cfg.steps, eval_loss, eval_error)?;
        info!(
            "done {}: final loss {final_loss:.4}, eval err {:.2}%, {:.2} steps/s",
            self.cfg.artifact,
            eval_error * 100.0,
            steps_per_sec
        );

        let store = runtime::state_to_store(&state, &self.manifest.state)?;
        Ok(TrainResult {
            final_loss,
            eval_error,
            eval_loss,
            loss_history: metrics.loss_history().to_vec(),
            state: store,
            steps_per_sec,
            manifest: self.manifest.clone(),
        })
    }

    fn make_prefetcher(&self, ds: Arc<dyn Dataset>) -> Prefetcher {
        // Prefetcher is generic over concrete datasets; re-wrap the trait
        // object in a small adapter.
        struct DynDs(Arc<dyn Dataset>);
        impl Dataset for DynDs {
            fn len(&self) -> usize {
                self.0.len()
            }
            fn input_elems(&self) -> usize {
                self.0.input_elems()
            }
            fn target_elems(&self) -> usize {
                self.0.target_elems()
            }
            fn sample(&self, idx: usize, x: &mut [f32], t: &mut [f32],
                      rng: &mut crate::util::Rng) {
                self.0.sample(idx, x, t, rng)
            }
        }
        Prefetcher::new(
            Arc::new(DynDs(ds)),
            self.manifest.batch_size,
            self.cfg.seed,
            self.cfg.workers,
            4,
        )
    }

    fn checkpoint(&self, state: &[xla::Literal], step: u64) -> Result<()> {
        if let Some(dir) = &self.cfg.checkpoint_dir {
            std::fs::create_dir_all(dir)?;
            let store = runtime::state_to_store(state, &self.manifest.state)?;
            let path: PathBuf =
                dir.join(format!("{}_{step}.ckpt", self.cfg.artifact));
            crate::params::checkpoint::save(&store, step, &path)?;
            crate::params::checkpoint::rotate(dir, &self.cfg.artifact,
                                              self.cfg.keep_checkpoints)?;
            info!("  checkpoint @ {step} -> {}", path.display());
        }
        Ok(())
    }

    /// Resume state literals from a checkpoint file.
    pub fn state_from_checkpoint(&self, path: &std::path::Path)
                                 -> Result<(Vec<xla::Literal>, u64)> {
        let (store, step) = crate::params::checkpoint::load(path)?;
        let state = runtime::store_to_state(&store, &self.manifest.state)?;
        Ok((state, step))
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

