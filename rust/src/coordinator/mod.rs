//! L3 coordinator: the training orchestrator around the AOT artifacts
//! (trainer loop, LR/pruning/INQ schedules, metrics, evaluation, sweeps).

pub mod metrics;
pub mod schedule;
pub mod sweep;
pub mod trainer;

pub use metrics::Metrics;
pub use schedule::LrSchedule;
pub use trainer::{TrainResult, Trainer};
