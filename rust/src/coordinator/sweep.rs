//! Experiment sweeps: run a list of training configs and collect one result
//! row per run — the engine behind the paper-table benches.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::runtime::Runtime;

use super::trainer::{TrainResult, Trainer};

/// One row of a results table.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub label: String,
    pub artifact: String,
    pub eval_error: f32,
    pub final_loss: f32,
    pub steps_per_sec: f64,
    /// free-form extras appended to the printed row (e.g. footprint)
    pub extra: Vec<(String, String)>,
}

pub struct Sweep<'rt> {
    rt: &'rt Runtime,
    pub rows: Vec<SweepRow>,
}

impl<'rt> Sweep<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        Sweep { rt, rows: Vec::new() }
    }

    /// Train one config and record a row. Returns the full result for
    /// callers that need the final state (export, footprints, mAP).
    pub fn run(&mut self, label: &str, cfg: TrainConfig)
               -> Result<TrainResult> {
        let trainer = Trainer::new(self.rt, cfg)?;
        let res = trainer.run()?;
        self.rows.push(SweepRow {
            label: label.to_string(),
            artifact: trainer.cfg.artifact.clone(),
            eval_error: res.eval_error,
            final_loss: res.final_loss,
            steps_per_sec: res.steps_per_sec,
            extra: Vec::new(),
        });
        Ok(res)
    }

    pub fn annotate_last(&mut self, key: &str, value: String) {
        if let Some(row) = self.rows.last_mut() {
            row.extra.push((key.to_string(), value));
        }
    }

    /// Render rows as a markdown table (printed by the benches; compare
    /// against the corresponding paper table in EXPERIMENTS.md).
    pub fn to_markdown(&self, title: &str) -> String {
        rows_to_markdown(&self.rows, title)
    }
}

/// Render result rows as a markdown table.
pub fn rows_to_markdown(rows: &[SweepRow], title: &str) -> String {
    let mut s = format!("\n## {title}\n\n");
    s.push_str("| run | artifact | val error | final loss | steps/s |");
    let extra_keys: Vec<String> = rows
        .iter()
        .flat_map(|r| r.extra.iter().map(|(k, _)| k.clone()))
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    for k in &extra_keys {
        s.push_str(&format!(" {k} |"));
    }
    s.push('\n');
    s.push_str("|---|---|---|---|---|");
    for _ in &extra_keys {
        s.push_str("---|");
    }
    s.push('\n');
    for r in rows {
        let err = if r.eval_error.is_nan() {
            "-".to_string()
        } else {
            format!("{:.2}%", r.eval_error * 100.0)
        };
        s.push_str(&format!(
            "| {} | {} | {} | {:.4} | {:.2} |",
            r.label, r.artifact, err, r.final_loss, r.steps_per_sec
        ));
        for k in &extra_keys {
            let v = r
                .extra
                .iter()
                .find(|(key, _)| key == k)
                .map(|(_, v)| v.as_str())
                .unwrap_or("-");
            s.push_str(&format!(" {v} |"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_rows_and_extras() {
        let rows = vec![
            SweepRow {
                label: "fp32".into(),
                artifact: "cifar_fp32".into(),
                eval_error: 0.123,
                final_loss: 0.5,
                steps_per_sec: 10.0,
                extra: vec![("memory".into(), "1.0 MB".into())],
            },
            SweepRow {
                label: "lutq4".into(),
                artifact: "cifar_lutq4".into(),
                eval_error: f32::NAN,
                final_loss: 0.6,
                steps_per_sec: 9.0,
                extra: vec![],
            },
        ];
        let md = rows_to_markdown(&rows, "Table X");
        assert!(md.contains("| fp32 | cifar_fp32 | 12.30% | 0.5000 | 10.00 | 1.0 MB |"));
        assert!(md.contains("| lutq4 | cifar_lutq4 | - | 0.6000 | 9.00 | - |"));
    }
}
