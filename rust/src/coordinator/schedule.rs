//! Learning-rate schedules for the trainer: constant, step decay and
//! warmup-cosine (the standard recipes for the paper's ResNet training).

#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant {
        lr: f32,
    },
    /// lr * gamma^(number of milestones passed)
    Step {
        lr: f32,
        gamma: f32,
        milestones: Vec<usize>,
    },
    /// linear warmup then cosine decay to ~0 over total_steps
    Cosine {
        peak: f32,
        total_steps: usize,
        warmup: usize,
    },
}

impl LrSchedule {
    pub fn constant(lr: f32) -> Self {
        LrSchedule::Constant { lr }
    }

    pub fn step(lr: f32, gamma: f32, milestones: Vec<usize>) -> Self {
        LrSchedule::Step { lr, gamma, milestones }
    }

    pub fn cosine(peak: f32, total_steps: usize, warmup: usize) -> Self {
        LrSchedule::Cosine { peak, total_steps, warmup }
    }

    pub fn at(&self, step: usize) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::Step { lr, gamma, milestones } => {
                let passed =
                    milestones.iter().filter(|&&m| step >= m).count();
                lr * gamma.powi(passed as i32)
            }
            LrSchedule::Cosine { peak, total_steps, warmup } => {
                if step < *warmup {
                    return peak * (step as f32 + 1.0) / *warmup as f32;
                }
                let t = (step - warmup) as f32
                    / (*total_steps - *warmup).max(1) as f32;
                let t = t.min(1.0);
                peak * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// Rebase a schedule onto a different total step count (keeps shape).
    pub fn rescaled(&self, new_total: usize) -> Self {
        match self {
            LrSchedule::Constant { lr } => LrSchedule::Constant { lr: *lr },
            LrSchedule::Step { lr, gamma, milestones } => {
                let old_max = milestones.iter().max().copied().unwrap_or(1);
                LrSchedule::Step {
                    lr: *lr,
                    gamma: *gamma,
                    milestones: milestones
                        .iter()
                        .map(|&m| m * new_total / old_max.max(1))
                        .collect(),
                }
            }
            LrSchedule::Cosine { peak, total_steps, warmup } => {
                LrSchedule::Cosine {
                    peak: *peak,
                    total_steps: new_total,
                    warmup: (warmup * new_total
                        / (*total_steps).max(1)).max(1),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_flat() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn step_decays_at_milestones() {
        let s = LrSchedule::step(1.0, 0.1, vec![100, 200]);
        assert_eq!(s.at(99), 1.0);
        assert!((s.at(100) - 0.1).abs() < 1e-7);
        assert!((s.at(250) - 0.01).abs() < 1e-8);
    }

    #[test]
    fn cosine_warms_up_then_decays() {
        let s = LrSchedule::cosine(0.5, 100, 10);
        assert!(s.at(0) < 0.1); // warming
        assert!((s.at(9) - 0.5).abs() < 0.01); // peak reached
        assert!(s.at(50) < 0.5);
        assert!(s.at(99) < 0.01); // near zero at the end
        // monotone decay after warmup
        let mut prev = s.at(10);
        for t in 11..100 {
            let v = s.at(t);
            assert!(v <= prev + 1e-7);
            prev = v;
        }
    }

    #[test]
    fn rescale_keeps_shape() {
        let s = LrSchedule::cosine(0.1, 300, 30).rescaled(100);
        assert!((s.at(9) - 0.1).abs() < 0.02); // warmup now ~10 steps
        assert!(s.at(99) < 0.01);
        let st = LrSchedule::step(1.0, 0.5, vec![150, 300]).rescaled(100);
        assert_eq!(st.at(49), 1.0);
        assert!((st.at(50) - 0.5).abs() < 1e-7);
    }
}
