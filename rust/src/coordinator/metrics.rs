//! Training metrics: console progress + JSONL event log (one JSON object
//! per line — easy to post-process into the report tables).

use std::io::Write;
use std::path::Path;

use crate::jsonic::Json;
use crate::util::Summary;

pub struct Metrics {
    file: Option<std::io::BufWriter<std::fs::File>>,
    pub loss: Summary,
    pub step_time_ms: Summary,
    history: Vec<(usize, f32)>,
}

impl Metrics {
    pub fn new(jsonl_path: Option<&Path>) -> std::io::Result<Self> {
        let file = match jsonl_path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                Some(std::io::BufWriter::new(std::fs::File::create(p)?))
            }
            None => None,
        };
        Ok(Metrics {
            file,
            loss: Summary::new(),
            step_time_ms: Summary::new(),
            history: Vec::new(),
        })
    }

    pub fn record_step(&mut self, step: usize, loss: f32, lr: f32,
                       ms: f64) -> std::io::Result<()> {
        self.loss.push(loss as f64);
        self.step_time_ms.push(ms);
        self.history.push((step, loss));
        self.write(Json::obj(vec![
            ("event", Json::str("step")),
            ("step", Json::num(step as f64)),
            ("loss", Json::num(loss as f64)),
            ("lr", Json::num(lr as f64)),
            ("ms", Json::num(ms)),
        ]))
    }

    pub fn record_eval(&mut self, step: usize, loss: f32,
                       error_rate: f32) -> std::io::Result<()> {
        self.write(Json::obj(vec![
            ("event", Json::str("eval")),
            ("step", Json::num(step as f64)),
            ("loss", Json::num(loss as f64)),
            ("error_rate", Json::num(error_rate as f64)),
        ]))
    }

    pub fn record_custom(&mut self, obj: Json) -> std::io::Result<()> {
        self.write(obj)
    }

    fn write(&mut self, j: Json) -> std::io::Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", j.to_string())?;
            f.flush()?;
        }
        Ok(())
    }

    /// (step, loss) sequence for loss-curve reporting.
    pub fn loss_history(&self) -> &[(usize, f32)] {
        &self.history
    }

    /// Mean loss over the last `n` recorded steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|(_, l)| l).sum::<f32>() / tail.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_lines_are_valid() {
        let path = std::env::temp_dir()
            .join(format!("lutq_metrics_{}.jsonl", std::process::id()));
        let mut m = Metrics::new(Some(&path)).unwrap();
        m.record_step(0, 2.3, 0.1, 40.0).unwrap();
        m.record_eval(0, 2.2, 0.9).unwrap();
        m.record_step(1, 2.1, 0.1, 39.0).unwrap();
        drop(m);
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        for l in lines {
            let j = crate::jsonic::parse(l).unwrap();
            assert!(j.get("event").is_some());
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn recent_loss_tail_mean() {
        let mut m = Metrics::new(None).unwrap();
        for (i, l) in [5.0f32, 4.0, 3.0, 2.0].iter().enumerate() {
            m.record_step(i, *l, 0.1, 1.0).unwrap();
        }
        assert!((m.recent_loss(2) - 2.5).abs() < 1e-6);
        assert!((m.recent_loss(100) - 3.5).abs() < 1e-6);
        assert_eq!(m.loss_history().len(), 4);
    }
}
