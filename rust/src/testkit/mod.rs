//! Mini property-testing harness (offline substitute for proptest).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` random inputs
//! drawn by `gen`; on failure it performs greedy shrinking via the
//! generator's `Shrink` implementation and reports the minimal failing
//! input with the seed needed to reproduce it.

use crate::util::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, ordered most-aggressive first.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut v = vec![0, self / 2];
        if *self > 1 {
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for u32 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut v = vec![0, self / 2];
        if *self > 1 {
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for u8 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut v = vec![0, self / 2];
        if *self > 1 {
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for String {
    fn shrinks(&self) -> Vec<Self> {
        if self.is_empty() {
            vec![]
        } else {
            vec![String::new(), self[..self.len() / 2].to_string()]
        }
    }
}

impl Shrink for f32 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        vec![0.0, self / 2.0, self.trunc()]
            .into_iter()
            .filter(|s| s != self)
            .collect()
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve
        out.push(self[..self.len() / 2].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink a single element
        if let Some(first_shrunk) = self[0].shrinks().into_iter().next() {
            let mut v = self.clone();
            v[0] = first_shrunk;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over random cases with shrinking on failure.
///
/// Panics with the minimal failing case. `gen` receives an Rng; `prop`
/// returns Ok(()) or Err(description).
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink
            let mut best = input;
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in best.shrinks() {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed {seed}, case {case}):\n  \
                 minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Deterministic synthetic LUT-Q models shared by the benches, the serve
/// tests and `lutq serve-bench --artifact synthetic` — perf and serving
/// paths stay exercisable without trained artifacts.
pub mod models {
    use crate::jsonic::Json;
    use crate::params::export::{LutLayer, QuantizedModel};
    use crate::params::HostTensor;
    use crate::quant::bitpack::pack_assignments;
    use crate::util::Rng;

    /// Per-sample input dims of [`synth_conv_model`].
    pub const CONV_INPUT: [usize; 3] = [32, 32, 3];

    /// Per-sample input dims of [`synth_mlp_model`].
    pub const MLP_INPUT: [usize; 1] = [16];

    /// 2-conv + GAP + head CNN over 32x32x3 with K-entry LUT layers.
    /// `pow2` draws the dictionary from ±2^e so shift-only execution
    /// works.
    pub fn synth_conv_model(k: usize,
                            pow2: bool) -> (Json, QuantizedModel) {
        let graph = crate::jsonic::parse(
            r#"[
            {"op":"conv","name":"c0","cin":3,"cout":16,"k":3,"stride":1},
            {"op":"bn","name":"b0","c":16},
            {"op":"relu"},
            {"op":"conv","name":"c1","cin":16,"cout":32,"k":3,"stride":2},
            {"op":"bn","name":"b1","c":32},
            {"op":"relu"},
            {"op":"gap"},
            {"op":"affine","name":"head","cin":32,"cout":10}
        ]"#,
        )
        .unwrap();
        let mut rng = Rng::new(7);
        let mut model = QuantizedModel::default();
        let dict: Vec<f32> = if pow2 {
            (0..k)
                .map(|i| {
                    let e = (i as i32 % 8) - 4;
                    let s = if i % 2 == 0 { 1.0 } else { -1.0 };
                    s * (e as f32).exp2()
                })
                .collect()
        } else {
            (0..k).map(|_| rng.normal() * 0.2).collect()
        };
        for (name, shape) in [("c0", vec![3, 3, 3, 16]),
                              ("c1", vec![3, 3, 16, 32]),
                              ("head", vec![32, 10])] {
            let n: usize = shape.iter().product();
            let assign: Vec<u32> =
                (0..n).map(|_| rng.below(k) as u32).collect();
            model.lut_layers.push(LutLayer::new(
                name,
                dict.clone(),
                pack_assignments(&assign, k),
                shape,
            ));
        }
        for (name, c) in [("b0", 16), ("b1", 32)] {
            model.fp.insert(format!("{name}.gamma"),
                            HostTensor::f32(vec![c], vec![1.0; c]));
            model.fp.insert(format!("{name}.beta"),
                            HostTensor::f32(vec![c], vec![0.0; c]));
            model.fp.insert(format!("{name}.rmean"),
                            HostTensor::f32(vec![c], vec![0.0; c]));
            model.fp.insert(format!("{name}.rvar"),
                            HostTensor::f32(vec![c], vec![1.0; c]));
        }
        model.fp.insert("head.b".into(),
                        HostTensor::f32(vec![10], vec![0.0; 10]));
        (graph, model)
    }

    /// Tiny LUT MLP (16 -> 32 -> 10) — the cheap end of the serving mix.
    pub fn synth_mlp_model(k: usize) -> (Json, QuantizedModel) {
        let graph = crate::jsonic::parse(
            r#"[
            {"op":"affine","name":"fc0","cin":16,"cout":32},
            {"op":"relu"},
            {"op":"affine","name":"fc1","cin":32,"cout":10}
        ]"#,
        )
        .unwrap();
        let mut rng = Rng::new(11);
        let dict: Vec<f32> = (0..k).map(|_| rng.normal() * 0.3).collect();
        let mut model = QuantizedModel::default();
        for (name, shape) in [("fc0", vec![16usize, 32]),
                              ("fc1", vec![32, 10])] {
            let n: usize = shape.iter().product();
            let assign: Vec<u32> =
                (0..n).map(|_| rng.below(k) as u32).collect();
            model.lut_layers.push(LutLayer::new(
                name,
                dict.clone(),
                pack_assignments(&assign, k),
                shape,
            ));
        }
        model.fp.insert("fc0.b".into(),
                        HostTensor::f32(vec![32], rng.normals(32)));
        model.fp.insert("fc1.b".into(),
                        HostTensor::f32(vec![10], rng.normals(10)));
        (graph, model)
    }
}

/// Fault injection for the cluster router tests: wrap any
/// [`Replica`](crate::serve::cluster::Replica) in a [`flaky::FlakyReplica`]
/// and it drops, delays or errors whole shards on a deterministic,
/// seeded schedule — no wall-clock in the schedule, so a failing run
/// reproduces from its seed alone.
pub mod flaky {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    use crate::serve::cluster::{Replica, ReplicaError};
    use crate::serve::registry::ModelInfo;
    use crate::util::Rng;

    /// What the schedule injects for one `predict_shard` call.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Fault {
        /// forward untouched
        None,
        /// swallow the shard — the router sees a transport-style loss
        Drop,
        /// fail the shard with an injected execution error
        Error,
        /// stall before forwarding (drives deadline-miss paths)
        Delay(Duration),
    }

    /// Per-call fault probabilities, rolled from a seeded [`Rng`]. The
    /// rolls are ordered drop, error, delay over one uniform draw, so
    /// `drop_p + error_p + delay_p <= 1.0` partitions the schedule.
    #[derive(Debug, Clone, Copy)]
    pub struct FaultPlan {
        pub drop_p: f32,
        pub error_p: f32,
        pub delay_p: f32,
        /// stall length for injected delays
        pub delay: Duration,
    }

    impl FaultPlan {
        /// Never inject (a transparent wrapper).
        pub fn none() -> FaultPlan {
            FaultPlan {
                drop_p: 0.0,
                error_p: 0.0,
                delay_p: 0.0,
                delay: Duration::ZERO,
            }
        }

        /// Every shard errors — the hard-down replica.
        pub fn always_error() -> FaultPlan {
            FaultPlan { error_p: 1.0, ..FaultPlan::none() }
        }

        /// Every shard is silently lost.
        pub fn always_drop() -> FaultPlan {
            FaultPlan { drop_p: 1.0, ..FaultPlan::none() }
        }

        /// Every shard stalls `delay` before being served — the slow
        /// replica that makes deadlines miss.
        pub fn always_delay(delay: Duration) -> FaultPlan {
            FaultPlan { delay_p: 1.0, delay, ..FaultPlan::none() }
        }
    }

    /// A [`Replica`] decorator injecting faults on a seeded schedule.
    /// Health probes and model listings pass through untouched, so the
    /// router's recovery path sees a replica that *looks* fine — the
    /// realistic flaky backend.
    pub struct FlakyReplica {
        inner: Box<dyn Replica>,
        plan: FaultPlan,
        rng: Mutex<Rng>,
        calls: AtomicU64,
        injected: AtomicU64,
    }

    impl FlakyReplica {
        pub fn new(inner: Box<dyn Replica>, seed: u64,
                   plan: FaultPlan) -> FlakyReplica {
            FlakyReplica {
                inner,
                plan,
                rng: Mutex::new(Rng::new(seed)),
                calls: AtomicU64::new(0),
                injected: AtomicU64::new(0),
            }
        }

        /// Shard calls seen so far.
        pub fn calls(&self) -> u64 {
            self.calls.load(Ordering::Relaxed)
        }

        /// Shard calls that had a fault injected.
        pub fn injected(&self) -> u64 {
            self.injected.load(Ordering::Relaxed)
        }

        fn next_fault(&self) -> Fault {
            let roll = self.rng.lock().unwrap().f32();
            let p = &self.plan;
            if roll < p.drop_p {
                Fault::Drop
            } else if roll < p.drop_p + p.error_p {
                Fault::Error
            } else if roll < p.drop_p + p.error_p + p.delay_p {
                Fault::Delay(p.delay)
            } else {
                Fault::None
            }
        }
    }

    impl Replica for FlakyReplica {
        fn name(&self) -> &str {
            self.inner.name()
        }

        fn predict_shard(
            &self,
            model: &str,
            samples: &[&[f32]],
            deadline: Option<Instant>,
        ) -> Result<Vec<Vec<f32>>, ReplicaError> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            match self.next_fault() {
                Fault::None => {
                    self.inner.predict_shard(model, samples, deadline)
                }
                Fault::Drop => {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    Err(ReplicaError::Failed(
                        "injected fault: shard dropped".to_string(),
                    ))
                }
                Fault::Error => {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    Err(ReplicaError::Failed(
                        "injected fault: shard errored".to_string(),
                    ))
                }
                Fault::Delay(d) => {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(d);
                    self.inner.predict_shard(model, samples, deadline)
                }
            }
        }

        fn check_health(&self) -> bool {
            self.inner.check_health()
        }

        fn model_infos(&self) -> anyhow::Result<Vec<ModelInfo>> {
            self.inner.model_infos()
        }

        fn ewma_hint_ms(&self) -> Option<f64> {
            self.inner.ewma_hint_ms()
        }

        fn metrics_hint_ms(&self) -> Option<f64> {
            self.inner.metrics_hint_ms()
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::Rng;

    pub fn f32_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
        let n = 1 + rng.below(max_len);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 100, |r| gen::f32_vec(r, 50, 1.0), |v| {
            if v.len() <= 50 {
                Ok(())
            } else {
                Err("too long".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            forall(2, 100, |r| gen::f32_vec(r, 50, 1.0), |v| {
                if v.len() < 5 {
                    Ok(())
                } else {
                    Err(format!("len {}", v.len()))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing vec has exactly 5 elements after shrinking
        assert!(msg.contains("len 5"), "{msg}");
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4usize, 2.0f32);
        let shrinks = t.shrinks();
        assert!(shrinks.iter().any(|(a, _)| *a < 4));
        assert!(shrinks.iter().any(|(_, b)| *b < 2.0));
    }
}
