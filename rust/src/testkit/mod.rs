//! Mini property-testing harness (offline substitute for proptest).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` random inputs
//! drawn by `gen`; on failure it performs greedy shrinking via the
//! generator's `Shrink` implementation and reports the minimal failing
//! input with the seed needed to reproduce it.

use crate::util::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, ordered most-aggressive first.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut v = vec![0, self / 2];
        if *self > 1 {
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for u32 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut v = vec![0, self / 2];
        if *self > 1 {
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for String {
    fn shrinks(&self) -> Vec<Self> {
        if self.is_empty() {
            vec![]
        } else {
            vec![String::new(), self[..self.len() / 2].to_string()]
        }
    }
}

impl Shrink for f32 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        vec![0.0, self / 2.0, self.trunc()]
            .into_iter()
            .filter(|s| s != self)
            .collect()
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve
        out.push(self[..self.len() / 2].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink a single element
        if let Some(first_shrunk) = self[0].shrinks().into_iter().next() {
            let mut v = self.clone();
            v[0] = first_shrunk;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over random cases with shrinking on failure.
///
/// Panics with the minimal failing case. `gen` receives an Rng; `prop`
/// returns Ok(()) or Err(description).
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink
            let mut best = input;
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in best.shrinks() {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed {seed}, case {case}):\n  \
                 minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::Rng;

    pub fn f32_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
        let n = 1 + rng.below(max_len);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 100, |r| gen::f32_vec(r, 50, 1.0), |v| {
            if v.len() <= 50 {
                Ok(())
            } else {
                Err("too long".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            forall(2, 100, |r| gen::f32_vec(r, 50, 1.0), |v| {
                if v.len() < 5 {
                    Ok(())
                } else {
                    Err(format!("len {}", v.len()))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing vec has exactly 5 elements after shrinking
        assert!(msg.contains("len 5"), "{msg}");
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4usize, 2.0f32);
        let shrinks = t.shrinks();
        assert!(shrinks.iter().any(|(a, _)| *a < 4));
        assert!(shrinks.iter().any(|(_, b)| *b < 2.0));
    }
}
