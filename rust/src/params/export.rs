//! Quantized model export — the deployable form of a LUT-Q network and the
//! concrete realization of the paper's memory claim: per quantized layer we
//! store the K-entry dictionary in fp32 plus ceil(log2 K)-bit packed
//! assignments (`K*B_float + N*ceil(log2 K)` bits), instead of `N*B_float`.
//!
//! The export bundles everything the pure-Rust inference engine needs:
//! packed quantized layers, full-precision leftovers (biases, BN params,
//! optionally first/last layers), and measured footprint stats.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use super::{HostTensor, ParamStore};
use crate::quant::bitpack::{bits_for, pack_assignments, unpack_assignments};
use crate::quant::pow2::{is_pow2_or_zero, pow2_round, Pow2};

/// One quantized layer: dictionary + packed assignments.
///
/// The bit-packed form is the storage/wire format; the execution planner
/// consumes the unpacked index view. Both the unpacked assignments and the
/// pow-2 shift form of the dictionary are computed once on first use and
/// cached, so repeated plan compiles (and the legacy per-call engine path)
/// never re-unpack. Mutating `dict`/`packed`/`shape` after a cached view
/// has been taken leaves the caches stale — treat layers as frozen once
/// they are being served.
#[derive(Debug, Clone, Default)]
pub struct LutLayer {
    pub name: String,
    pub dict: Vec<f32>,
    pub packed: Vec<u8>,
    pub shape: Vec<usize>,
    assign_cache: OnceLock<Vec<u32>>,
    shift_cache: OnceLock<Option<Vec<Pow2>>>,
}

impl LutLayer {
    pub fn new(name: impl Into<String>, dict: Vec<f32>, packed: Vec<u8>,
               shape: Vec<usize>) -> Self {
        LutLayer {
            name: name.into(),
            dict,
            packed,
            shape,
            assign_cache: OnceLock::new(),
            shift_cache: OnceLock::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.shape.iter().product()
    }

    /// Unpacked assignment indices (cached; unpacks once on first call).
    pub fn assignments(&self) -> &[u32] {
        self.assign_cache.get_or_init(|| {
            unpack_assignments(&self.packed, self.n(), self.dict.len())
        })
    }

    /// Shift (pow-2) view of the dictionary, rounded with the engine's
    /// exponent clamp. `None` unless every entry is 0 or ±2^k — i.e. the
    /// layer is eligible for shift-only execution. Cached.
    pub fn shift_dict(&self) -> Option<&[Pow2]> {
        self.shift_cache
            .get_or_init(|| {
                if self.dict.iter().all(|&d| is_pow2_or_zero(d)) {
                    Some(
                        self.dict
                            .iter()
                            .map(|&d| pow2_round(d, -40, 40))
                            .collect(),
                    )
                } else {
                    None
                }
            })
            .as_deref()
    }

    /// Reconstruct the tied weights Q = d[A].
    pub fn dequantize(&self) -> Vec<f32> {
        self.assignments()
            .iter()
            .map(|&a| self.dict[a as usize])
            .collect()
    }

    /// Stored bits: the paper's formula for this layer.
    pub fn stored_bits(&self) -> u64 {
        self.dict.len() as u64 * 32
            + self.n() as u64 * bits_for(self.dict.len()) as u64
    }

    /// True iff every dictionary entry is 0 or +-2^k (multiplier-less).
    pub fn is_multiplierless(&self) -> bool {
        self.dict.iter().all(|&d| is_pow2_or_zero(d))
    }

    /// Fraction of weights tied to exact zero (pruning sparsity).
    pub fn sparsity(&self) -> f32 {
        let a = self.assignments();
        let zero_entries: Vec<bool> =
            self.dict.iter().map(|&d| d == 0.0).collect();
        a.iter().filter(|&&i| zero_entries[i as usize]).count() as f32
            / a.len().max(1) as f32
    }
}

/// A deployable quantized model.
#[derive(Debug, Clone, Default)]
pub struct QuantizedModel {
    pub lut_layers: Vec<LutLayer>,
    /// full-precision tensors (biases, BN gamma/beta/rmean/rvar, fp layers)
    pub fp: BTreeMap<String, HostTensor>,
}

impl QuantizedModel {
    /// Build from artifact state: `q:<layer>.d` / `q:<layer>.A` pairs become
    /// packed LUT layers; `p:` params not covered by a LUT layer plus `bn:`
    /// state are kept fp32. Momentum (`m:`) is dropped (training-only).
    pub fn from_state(store: &ParamStore, qlayers: &[String]) -> Self {
        let mut model = QuantizedModel::default();
        for layer in qlayers {
            let d = store
                .get(&format!("q:{layer}.d"))
                .unwrap_or_else(|| panic!("missing dict for {layer}"));
            let a = store
                .get(&format!("q:{layer}.A"))
                .unwrap_or_else(|| panic!("missing assignments for {layer}"));
            let dict = d.as_f32().to_vec();
            let assigns: Vec<u32> =
                a.as_i32().iter().map(|&x| x as u32).collect();
            let packed = pack_assignments(&assigns, dict.len());
            model.lut_layers.push(LutLayer::new(
                layer.clone(),
                dict,
                packed,
                a.dims.clone(),
            ));
        }
        let lut_names: std::collections::HashSet<String> = qlayers
            .iter()
            .map(|l| format!("p:{l}.w"))
            .collect();
        for (name, t) in store.iter() {
            if name.starts_with("m:") || name.starts_with("q:") {
                continue;
            }
            if lut_names.contains(name) {
                continue; // replaced by the LUT layer
            }
            if let Some(stripped) = name.strip_prefix("p:") {
                model.fp.insert(stripped.to_string(), t.clone());
            } else if let Some(stripped) = name.strip_prefix("bn:") {
                model.fp.insert(stripped.to_string(), t.clone());
            }
        }
        model
    }

    pub fn lut(&self, name: &str) -> Option<&LutLayer> {
        self.lut_layers.iter().find(|l| l.name == name)
    }

    /// Total stored bytes (paper formula for LUT layers + fp32 leftovers).
    pub fn stored_bytes(&self) -> u64 {
        let lut_bits: u64 =
            self.lut_layers.iter().map(|l| l.stored_bits()).sum();
        let fp_bytes: u64 =
            self.fp.values().map(|t| t.byte_len() as u64).sum();
        lut_bits.div_ceil(8) + fp_bytes
    }

    /// Dense fp32 bytes of the same parameters (the comparison baseline).
    pub fn dense_bytes(&self) -> u64 {
        let lut: u64 = self.lut_layers.iter().map(|l| l.n() as u64 * 4).sum();
        let fp: u64 = self.fp.values().map(|t| t.byte_len() as u64).sum();
        lut + fp
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.stored_bytes() as f64
    }

    /// All quantized layers multiplier-less (pow-2 dictionaries)?
    pub fn is_multiplierless(&self) -> bool {
        self.lut_layers.iter().all(|l| l.is_multiplierless())
    }

    // ---------------------------------------------------------- serialize
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"LUTQMODL")?;
        f.write_all(&(self.lut_layers.len() as u32).to_le_bytes())?;
        for l in &self.lut_layers {
            write_str(&mut f, &l.name)?;
            f.write_all(&(l.dict.len() as u32).to_le_bytes())?;
            for d in &l.dict {
                f.write_all(&d.to_le_bytes())?;
            }
            f.write_all(&(l.shape.len() as u32).to_le_bytes())?;
            for &s in &l.shape {
                f.write_all(&(s as u64).to_le_bytes())?;
            }
            f.write_all(&(l.packed.len() as u64).to_le_bytes())?;
            f.write_all(&l.packed)?;
        }
        f.write_all(&(self.fp.len() as u32).to_le_bytes())?;
        for (name, t) in &self.fp {
            write_str(&mut f, name)?;
            f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for &d in &t.dims {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for x in t.as_f32() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Self> {
        use std::io::Read;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"LUTQMODL" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad model magic",
            ));
        }
        let nl = read_u32(&mut f)? as usize;
        let mut lut_layers = Vec::with_capacity(nl);
        for _ in 0..nl {
            let name = read_str(&mut f)?;
            let k = read_u32(&mut f)? as usize;
            let mut dict = Vec::with_capacity(k);
            for _ in 0..k {
                dict.push(read_f32(&mut f)?);
            }
            let nd = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(nd);
            for _ in 0..nd {
                shape.push(read_u64(&mut f)? as usize);
            }
            let plen = read_u64(&mut f)? as usize;
            let mut packed = vec![0u8; plen];
            f.read_exact(&mut packed)?;
            lut_layers.push(LutLayer::new(name, dict, packed, shape));
        }
        let nf = read_u32(&mut f)? as usize;
        let mut fp = BTreeMap::new();
        for _ in 0..nf {
            let name = read_str(&mut f)?;
            let nd = read_u32(&mut f)? as usize;
            let mut dims = Vec::with_capacity(nd);
            for _ in 0..nd {
                dims.push(read_u64(&mut f)? as usize);
            }
            let n: usize = dims.iter().product();
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(read_f32(&mut f)?);
            }
            fp.insert(name, HostTensor::f32(dims, data));
        }
        Ok(QuantizedModel { lut_layers, fp })
    }
}

fn write_str<W: std::io::Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn read_str<R: std::io::Read>(r: &mut R) -> std::io::Result<String> {
    let n = read_u32(r)? as usize;
    if n > 4096 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "string too long",
        ));
    }
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    String::from_utf8(b)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData,
                                         "bad utf8"))
}

fn read_u32<R: std::io::Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: std::io::Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32<R: std::io::Read>(r: &mut R) -> std::io::Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_store() -> (ParamStore, Vec<String>) {
        let mut rng = Rng::new(3);
        let mut s = ParamStore::new();
        let w: Vec<f32> = rng.normals(24);
        s.push("p:fc.w", HostTensor::f32(vec![4, 6], w));
        s.push("p:fc.b", HostTensor::f32(vec![6], rng.normals(6)));
        s.push("q:fc.d",
               HostTensor::f32(vec![4], vec![-0.5, 0.0, 0.25, 1.0]));
        s.push("q:fc.A", HostTensor::i32(
            vec![4, 6],
            (0..24).map(|i| (i % 4) as i32).collect()));
        s.push("bn:b0.rmean", HostTensor::zeros_f32(vec![6]));
        s.push("m:fc.w", HostTensor::zeros_f32(vec![24])); // dropped
        (s, vec!["fc".to_string()])
    }

    #[test]
    fn from_state_builds_layers() {
        let (s, q) = sample_store();
        let m = QuantizedModel::from_state(&s, &q);
        assert_eq!(m.lut_layers.len(), 1);
        let l = &m.lut_layers[0];
        assert_eq!(l.dict, vec![-0.5, 0.0, 0.25, 1.0]);
        assert_eq!(l.shape, vec![4, 6]);
        // fp keeps bias + bn, drops momentum and the tied weight
        assert!(m.fp.contains_key("fc.b"));
        assert!(m.fp.contains_key("b0.rmean"));
        assert!(!m.fp.contains_key("fc.w"));
        assert_eq!(m.fp.len(), 2);
    }

    #[test]
    fn dequantize_matches_gather() {
        let (s, q) = sample_store();
        let m = QuantizedModel::from_state(&s, &q);
        let l = &m.lut_layers[0];
        let deq = l.dequantize();
        let a = s.get("q:fc.A").unwrap().as_i32();
        let d = s.get("q:fc.d").unwrap().as_f32();
        for (x, &ai) in deq.iter().zip(a) {
            assert_eq!(*x, d[ai as usize]);
        }
    }

    #[test]
    fn stored_bits_formula() {
        let (s, q) = sample_store();
        let m = QuantizedModel::from_state(&s, &q);
        // K=4 -> 2 bits per weight, N=24: 4*32 + 24*2 = 176 bits
        assert_eq!(m.lut_layers[0].stored_bits(), 176);
    }

    #[test]
    fn multiplierless_predicate() {
        let (s, q) = sample_store();
        let mut m = QuantizedModel::from_state(&s, &q);
        // -0.5, 0, 0.25, 1.0 are all pow2-or-zero
        assert!(m.is_multiplierless());
        m.lut_layers[0].dict[2] = 0.3; // not a power of two
        assert!(!m.is_multiplierless());
    }

    #[test]
    fn save_load_roundtrip() {
        let (s, q) = sample_store();
        let m = QuantizedModel::from_state(&s, &q);
        let path = std::env::temp_dir()
            .join(format!("lutq_model_{}.bin", std::process::id()));
        m.save(&path).unwrap();
        let l = QuantizedModel::load(&path).unwrap();
        assert_eq!(l.lut_layers[0].dict, m.lut_layers[0].dict);
        assert_eq!(l.lut_layers[0].packed, m.lut_layers[0].packed);
        assert_eq!(l.fp.len(), m.fp.len());
        assert_eq!(l.stored_bytes(), m.stored_bytes());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn sparsity_counts_zero_assignments() {
        let l = LutLayer::new(
            "x",
            vec![0.0, 1.0],
            pack_assignments(&[0, 0, 1, 0], 2),
            vec![4],
        );
        assert_eq!(l.sparsity(), 0.75);
    }

    #[test]
    fn cached_views_are_consistent() {
        let assigns = [0u32, 2, 1, 3, 3, 0];
        let l = LutLayer::new(
            "c",
            vec![-0.5, 0.0, 0.25, 1.0],
            pack_assignments(&assigns, 4),
            vec![6],
        );
        // repeated calls return the same unpacked view
        assert_eq!(l.assignments(), &assigns);
        assert_eq!(l.assignments().as_ptr(), l.assignments().as_ptr());
        // pow-2 dictionary -> shift view exists and matches to_f32
        let sd = l.shift_dict().expect("pow2 dict");
        for (p, d) in sd.iter().zip(&l.dict) {
            assert_eq!(p.to_f32(), *d);
        }
        // non-pow2 dictionary -> no shift view
        let l2 = LutLayer::new("d", vec![0.3, 1.0],
                               pack_assignments(&[0, 1], 2), vec![2]);
        assert!(l2.shift_dict().is_none());
    }
}
