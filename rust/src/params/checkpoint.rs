//! Checkpoint format: a simple self-describing binary container for a
//! [`ParamStore`] (magic + version + entry table), with CRC-less integrity
//! via length checks. Used by the trainer for periodic snapshots and
//! resume.
//!
//! Layout (little endian):
//!   b"LUTQCKPT" | u32 version | u64 step | u32 n_entries
//!   per entry: u32 name_len | name | u8 dtype | u32 ndim | u64 dims[]
//!              | u64 byte_len | raw data

use std::io::{self, Read, Write};
use std::path::Path;

use super::{HostTensor, ParamStore, TensorData};

const MAGIC: &[u8; 8] = b"LUTQCKPT";
const VERSION: u32 = 1;

pub fn save(store: &ParamStore, step: u64, path: &Path) -> io::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&step.to_le_bytes())?;
        f.write_all(&(store.len() as u32).to_le_bytes())?;
        for (name, t) in store.iter() {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[t.dtype_tag()])?;
            f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for &d in &t.dims {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            match &t.data {
                TensorData::F32(v) => {
                    f.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
                TensorData::I32(v) => {
                    f.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        f.flush()?;
    }
    std::fs::rename(&tmp, path) // atomic publish
}

pub fn load(path: &Path) -> io::Result<(ParamStore, u64)> {
    let mut f = io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic"));
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        return Err(bad(&format!("unsupported version {version}")));
    }
    let step = read_u64(&mut f)?;
    let n = read_u32(&mut f)? as usize;
    let mut store = ParamStore::new();
    for _ in 0..n {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            return Err(bad("name too long"));
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|_| bad("bad name"))?;
        let mut dtype = [0u8; 1];
        f.read_exact(&mut dtype)?;
        let ndim = read_u32(&mut f)? as usize;
        if ndim > 16 {
            return Err(bad("too many dims"));
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u64(&mut f)? as usize);
        }
        let byte_len = read_u64(&mut f)? as usize;
        let elems: usize = dims.iter().product();
        if byte_len != elems * 4 {
            return Err(bad(&format!(
                "tensor `{name}`: byte_len {byte_len} != dims {dims:?}"
            )));
        }
        let mut raw = vec![0u8; byte_len];
        f.read_exact(&mut raw)?;
        let t = match dtype[0] {
            0 => HostTensor::f32(
                dims,
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            1 => HostTensor::i32(
                dims,
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            d => return Err(bad(&format!("bad dtype {d}"))),
        };
        store.push(&name, t);
    }
    Ok((store, step))
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("checkpoint: {msg}"))
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Keep the most recent `keep` checkpoints matching `prefix` in `dir`.
pub fn rotate(dir: &Path, prefix: &str, keep: usize) -> io::Result<()> {
    let mut found: Vec<(u64, std::path::PathBuf)> = Vec::new();
    for e in std::fs::read_dir(dir)? {
        let e = e?;
        let name = e.file_name().to_string_lossy().to_string();
        if let Some(rest) = name.strip_prefix(prefix) {
            if let Some(num) = rest
                .strip_prefix('_')
                .and_then(|s| s.strip_suffix(".ckpt"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                found.push((num, e.path()));
            }
        }
    }
    found.sort();
    while found.len() > keep {
        let (_, path) = found.remove(0);
        std::fs::remove_file(path)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("lutq_ckpt_test_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("rt");
        let mut s = ParamStore::new();
        s.push("p:conv.w", HostTensor::f32(vec![2, 3], vec![1., -2., 3.5,
                                                            0., 9., -0.25]));
        s.push("q:conv.A", HostTensor::i32(vec![6], vec![0, 1, 2, 3, 0, 1]));
        let path = dir.join("test_100.ckpt");
        save(&s, 100, &path).unwrap();
        let (loaded, step) = load(&path).unwrap();
        assert_eq!(step, 100);
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.get("p:conv.w").unwrap(), s.get("p:conv.w").unwrap());
        assert_eq!(loaded.get("q:conv.A").unwrap(), s.get("q:conv.A").unwrap());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = tmpdir("bad");
        let path = dir.join("x.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn truncated_file_rejected() {
        let dir = tmpdir("trunc");
        let mut s = ParamStore::new();
        s.push("a", HostTensor::f32(vec![100], vec![0.5; 100]));
        let path = dir.join("t_1.ckpt");
        save(&s, 1, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn rotation_keeps_newest() {
        let dir = tmpdir("rot");
        let s = ParamStore::new();
        for step in [10u64, 20, 30, 40] {
            save(&s, step, &dir.join(format!("run_{step}.ckpt"))).unwrap();
        }
        rotate(&dir, "run", 2).unwrap();
        let mut left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .collect();
        left.sort();
        assert_eq!(left, vec!["run_30.ckpt", "run_40.ckpt"]);
        std::fs::remove_dir_all(dir).unwrap();
    }
}
