//! Host-side parameter management: named tensors in artifact state order,
//! checkpointing, and the packed quantized-model export format.

pub mod checkpoint;
pub mod export;

use std::collections::HashMap;

/// Element data of a host tensor (artifacts use f32 everywhere except the
/// int32 assignment matrices).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor::f32(dims, vec![0.0; n])
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn dtype_tag(&self) -> u8 {
        match self.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
        }
    }

    pub fn byte_len(&self) -> usize {
        self.elems() * 4
    }
}

/// Ordered, named tensor collection mirroring the artifact state layout.
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    entries: Vec<(String, HostTensor)>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, t: HostTensor) {
        assert!(
            !self.index.contains_key(name),
            "duplicate tensor `{name}`"
        );
        self.index.insert(name.to_string(), self.entries.len());
        self.entries.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.index.get(name).map(|&i| &self.entries[i].1)
    }

    pub fn set(&mut self, name: &str, t: HostTensor) {
        match self.index.get(name) {
            Some(&i) => self.entries[i].1 = t,
            None => self.push(name, t),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &HostTensor)> {
        self.entries.iter().map(|(n, t)| (n.as_str(), t))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Total parameter bytes at fp32 (the dense footprint baseline).
    pub fn total_bytes(&self) -> u64 {
        self.entries.iter().map(|(_, t)| t.byte_len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut s = ParamStore::new();
        s.push("a", HostTensor::f32(vec![2, 2], vec![1., 2., 3., 4.]));
        s.push("b", HostTensor::i32(vec![3], vec![1, 2, 3]));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("a").unwrap().as_f32(), &[1., 2., 3., 4.]);
        assert_eq!(s.get("b").unwrap().as_i32(), &[1, 2, 3]);
        assert!(s.get("c").is_none());
        assert_eq!(s.total_bytes(), 16 + 12);
    }

    #[test]
    fn set_replaces() {
        let mut s = ParamStore::new();
        s.push("a", HostTensor::zeros_f32(vec![2]));
        s.set("a", HostTensor::f32(vec![2], vec![5., 6.]));
        assert_eq!(s.get("a").unwrap().as_f32(), &[5., 6.]);
        assert_eq!(s.len(), 1);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_push_panics() {
        let mut s = ParamStore::new();
        s.push("a", HostTensor::zeros_f32(vec![1]));
        s.push("a", HostTensor::zeros_f32(vec![1]));
    }

    #[test]
    #[should_panic(expected = "not f32")]
    fn wrong_dtype_access_panics() {
        let t = HostTensor::i32(vec![1], vec![1]);
        t.as_f32();
    }
}
