//! A compiled artifact program and its typed execute interface.
//!
//! All artifact programs are lowered with `return_tuple=True`, so a run
//! returns one tuple literal; [`TupleOut`] wraps its decomposition with
//! spec-checked accessors.

use anyhow::{ensure, Context, Result};

use super::manifest::ProgramSpec;

pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ProgramSpec,
}

/// Decomposed tuple output of one program run, in manifest output order.
pub struct TupleOut {
    pub parts: Vec<xla::Literal>,
}

impl TupleOut {
    pub fn f32_scalar(&self, idx: usize) -> Result<f32> {
        self.parts[idx]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("scalar out {idx}: {e:?}"))
    }

    pub fn f32_vec(&self, idx: usize) -> Result<Vec<f32>> {
        self.parts[idx]
            .to_vec()
            .map_err(|e| anyhow::anyhow!("vec out {idx}: {e:?}"))
    }

    /// Consume, splitting off the first `n` parts; returns (head, tail).
    pub fn split_off(mut self, n: usize) -> (Vec<xla::Literal>, Vec<xla::Literal>) {
        let tail = self.parts.split_off(n);
        (self.parts, tail)
    }
}

impl Program {
    pub(super) fn new(exe: xla::PjRtLoadedExecutable,
                      spec: ProgramSpec) -> Self {
        Program { exe, spec }
    }

    pub fn input_count(&self) -> usize {
        self.spec.inputs.len()
    }

    pub fn output_count(&self) -> usize {
        self.spec.outputs.len()
    }

    /// Execute with spec-validated literal inputs; returns the decomposed
    /// tuple output. Accepts owned literals or references (`&Literal`) —
    /// the eval hot path passes the state by reference so it is uploaded
    /// without host-side cloning.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self, args: &[L]) -> Result<TupleOut> {
        ensure!(
            args.len() == self.spec.inputs.len(),
            "program expects {} inputs, got {}",
            self.spec.inputs.len(),
            args.len()
        );
        let result = self
            .exe
            .execute::<L>(args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "program returned {} outputs, manifest says {}",
            parts.len(),
            self.spec.outputs.len()
        );
        Ok(TupleOut { parts })
    }

    /// Validate that literal args match the manifest input specs (debug aid
    /// used by integration tests and the trainer's first step).
    pub fn check_args<L: std::borrow::Borrow<xla::Literal>>(
        &self, args: &[L]) -> Result<()> {
        ensure!(args.len() == self.spec.inputs.len(), "arity mismatch");
        for (a, spec) in args.iter().zip(&self.spec.inputs) {
            let n = a.borrow().element_count();
            ensure!(
                n == spec.elems(),
                "input `{}`: {} elems, expected {} {:?}",
                spec.name,
                n,
                spec.elems(),
                spec.shape
            );
        }
        Ok(())
    }
}

/// Helper: run `init` and return the state literal vector.
pub fn run_init(prog: &Program, seed: i32) -> Result<Vec<xla::Literal>> {
    let out = prog
        .run(&[super::scalar_i32(seed)])
        .context("run init")?;
    Ok(out.parts)
}
