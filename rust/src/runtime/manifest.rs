//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parses `artifacts/<name>/manifest.json` into typed specs
//! (program I/O signatures, the ordered state layout, model metadata and
//! the layer-IR graph consumed by the inference engine).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonic::{self, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype `{other}`"),
        }
    }
}

/// One tensor in a program signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j
                .at("name")
                .as_str()
                .ok_or_else(|| anyhow!("spec name"))?
                .to_string(),
            shape: j.at("shape").as_shape().ok_or_else(|| anyhow!("shape"))?,
            dtype: Dtype::parse(
                j.at("dtype").as_str().ok_or_else(|| anyhow!("dtype"))?,
            )?,
        })
    }
}

/// One AOT-compiled program (init / train_step / eval_step / infer).
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One entry of the flat state layout.
#[derive(Debug, Clone)]
pub struct StateEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    /// param | dict | assign | bnstate | momentum
    pub role: String,
}

/// Model metadata (mirrors `meta` from models.py).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub arch: String,
    pub input: Vec<usize>,
    pub num_classes: usize,
    pub head: String,
    pub grid: usize, // 0 unless detect head
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub dir: PathBuf,
    pub meta: ModelMeta,
    pub qlayers: Vec<String>,
    pub state: Vec<StateEntry>,
    pub batch_size: usize,
    /// quant config echo (method, bits, pow2, mlbn, act_bits, prune)
    pub quant: Json,
    /// layer-IR graph for the Rust inference engine
    pub graph: Json,
    programs: std::collections::BTreeMap<String, ProgramSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let j = jsonic::parse_file(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let meta = j.at("meta");
        let programs = j
            .at("programs")
            .as_obj()
            .ok_or_else(|| anyhow!("programs"))?
            .iter()
            .map(|(name, p)| {
                let inputs = p
                    .at("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = p
                    .at("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok((
                    name.clone(),
                    ProgramSpec {
                        file: dir.join(
                            p.at("file")
                                .as_str()
                                .ok_or_else(|| anyhow!("file"))?,
                        ),
                        inputs,
                        outputs,
                    },
                ))
            })
            .collect::<Result<_>>()?;

        let state = j
            .at("state")
            .as_arr()
            .ok_or_else(|| anyhow!("state"))?
            .iter()
            .map(|e| {
                Ok(StateEntry {
                    name: e.at("name").as_str().unwrap_or("").to_string(),
                    shape: e
                        .at("shape")
                        .as_shape()
                        .ok_or_else(|| anyhow!("state shape"))?,
                    dtype: Dtype::parse(e.at("dtype").as_str().unwrap_or(""))?,
                    role: e.at("role").as_str().unwrap_or("").to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            name: j.at("name").as_str().unwrap_or("").to_string(),
            dir: dir.to_path_buf(),
            meta: ModelMeta {
                arch: meta.at("arch").as_str().unwrap_or("").to_string(),
                input: meta
                    .at("input")
                    .as_shape()
                    .ok_or_else(|| anyhow!("meta input"))?,
                num_classes: meta
                    .at("num_classes")
                    .as_usize()
                    .context("num_classes")?,
                head: meta.at("head").as_str().unwrap_or("").to_string(),
                grid: meta
                    .get("grid")
                    .and_then(|g| g.as_usize())
                    .unwrap_or(0),
            },
            qlayers: j
                .at("qlayers")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(|s| s.to_string()))
                .collect(),
            state,
            batch_size: j
                .at("config")
                .at("batch_size")
                .as_usize()
                .context("batch_size")?,
            quant: j.at("config").at("quant").clone(),
            graph: j.at("graph").clone(),
            programs,
        })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{}` has no program `{name}`",
                                   self.name))
    }

    pub fn program_names(&self) -> Vec<&str> {
        self.programs.keys().map(|s| s.as_str()).collect()
    }

    /// Quant-config accessors with defaults.
    pub fn quant_method(&self) -> &str {
        self.quant
            .get("method")
            .and_then(|m| m.as_str())
            .unwrap_or("none")
    }

    pub fn quant_bits(&self) -> usize {
        self.quant.get("bits").and_then(|b| b.as_usize()).unwrap_or(32)
    }

    pub fn dict_size(&self) -> usize {
        1usize << self.quant_bits().min(24)
    }

    pub fn act_bits(&self) -> usize {
        self.quant
            .get("act_bits")
            .and_then(|b| b.as_usize())
            .unwrap_or(0)
    }

    pub fn mlbn(&self) -> bool {
        self.quant.get("mlbn").and_then(|b| b.as_bool()).unwrap_or(false)
    }

    pub fn pow2(&self) -> bool {
        self.quant.get("pow2").and_then(|b| b.as_bool()).unwrap_or(false)
    }

    /// Total parameter count (param-role entries only).
    pub fn param_count(&self) -> u64 {
        self.state
            .iter()
            .filter(|e| e.role == "param")
            .map(|e| e.shape.iter().product::<usize>() as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "t",
      "config": {"batch_size": 4, "quant": {"method":"lutq","bits":2,
                 "pow2":true,"act_bits":8,"mlbn":false}},
      "meta": {"arch": "mlp", "input": [8], "num_classes": 3,
               "head": "classify"},
      "qlayers": ["fc0"],
      "graph": [{"op":"affine","name":"fc0","cin":8,"cout":3}],
      "state": [
        {"name":"p:fc0.w","shape":[8,3],"dtype":"f32","role":"param"},
        {"name":"q:fc0.d","shape":[4],"dtype":"f32","role":"dict"},
        {"name":"q:fc0.A","shape":[8,3],"dtype":"i32","role":"assign"}
      ],
      "programs": {
        "infer": {"file":"infer.hlo.txt",
          "inputs":[{"name":"x","shape":[4,8],"dtype":"f32"}],
          "outputs":[{"name":"out","shape":[4,3],"dtype":"f32"}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = crate::jsonic::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.batch_size, 4);
        assert_eq!(m.meta.num_classes, 3);
        assert_eq!(m.qlayers, vec!["fc0"]);
        assert_eq!(m.state.len(), 3);
        assert_eq!(m.state[2].dtype, Dtype::I32);
        assert_eq!(m.quant_method(), "lutq");
        assert_eq!(m.dict_size(), 4);
        assert!(m.pow2());
        assert_eq!(m.act_bits(), 8);
        let p = m.program("infer").unwrap();
        assert_eq!(p.inputs[0].shape, vec![4, 8]);
        assert!(m.program("nope").is_err());
        assert_eq!(m.param_count(), 24);
    }
}
