//! PJRT runtime: loads AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//! This is the only place Rust touches XLA; python never runs here.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto
//! (text parser reassigns the 64-bit jax ids that xla_extension 0.5.1
//! rejects) -> XlaComputation -> client.compile -> execute.

pub mod executable;
pub mod manifest;

pub use executable::{Program, TupleOut};
pub use manifest::{Dtype, Manifest, ProgramSpec, StateEntry, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::params::{HostTensor, ParamStore, TensorData};

/// Shared PJRT CPU client + executable cache over an artifacts directory.
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
    root: PathBuf,
    cache: Mutex<HashMap<(String, String), Arc<Program>>>,
}

impl Runtime {
    pub fn new(artifacts_root: &Path) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client: Arc::new(client),
            root: artifacts_root.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_root(&self) -> &Path {
        &self.root
    }

    /// Load the manifest of an artifact by preset name.
    pub fn manifest(&self, artifact: &str) -> Result<Manifest> {
        Manifest::load(&self.root.join(artifact)).with_context(|| {
            format!(
                "load artifact `{artifact}` from {} (run `make artifacts`?)",
                self.root.display()
            )
        })
    }

    /// Compile (or fetch cached) a program of an artifact.
    pub fn load_program(&self, man: &Manifest, program: &str)
                        -> Result<Arc<Program>> {
        let key = (man.name.clone(), program.to_string());
        if let Some(p) = self.cache.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let spec = man.program(program)?;
        let t = crate::util::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("path utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {program}: {e:?}"))?;
        crate::debug!("compiled {}/{program} in {:.2}s", man.name,
                      t.elapsed_s());
        let prog = Arc::new(Program::new(exe, spec.clone()));
        self.cache.lock().unwrap().insert(key, prog.clone());
        Ok(prog)
    }
}

// ---------------------------------------------------------------------
// Literal <-> host conversions
// ---------------------------------------------------------------------

/// Build an f32 literal with the given shape from a host slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = f32_bytes(data);
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        &bytes,
    )
    .map_err(|e| anyhow::anyhow!("literal_f32: {e:?}"))
}

/// Build an i32 literal with the given shape from a host slice.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        &bytes,
    )
    .map_err(|e| anyhow::anyhow!("literal_i32: {e:?}"))
}

/// Scalar f32 literal.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Scalar i32 literal.
pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

fn f32_bytes(data: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    bytes
}

/// Convert a literal back to a HostTensor using the manifest spec's shape
/// and dtype (the literal's own shape is validated against it).
pub fn literal_to_host(lit: &xla::Literal, spec_shape: &[usize],
                       dtype: Dtype) -> Result<HostTensor> {
    let n: usize = spec_shape.iter().product();
    match dtype {
        Dtype::F32 => {
            let v: Vec<f32> = lit
                .to_vec()
                .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?;
            anyhow::ensure!(v.len() == n, "elem mismatch {} vs {n}", v.len());
            Ok(HostTensor::f32(spec_shape.to_vec(), v))
        }
        Dtype::I32 => {
            let v: Vec<i32> = lit
                .to_vec()
                .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?;
            anyhow::ensure!(v.len() == n, "elem mismatch {} vs {n}", v.len());
            Ok(HostTensor::i32(spec_shape.to_vec(), v))
        }
    }
}

/// Convert a HostTensor to a literal.
pub fn host_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    match &t.data {
        TensorData::F32(v) => literal_f32(&t.dims, v),
        TensorData::I32(v) => literal_i32(&t.dims, v),
    }
}

/// Convert a full state literal vector into a named ParamStore using the
/// manifest state layout.
pub fn state_to_store(state: &[xla::Literal], entries: &[StateEntry])
                      -> Result<ParamStore> {
    anyhow::ensure!(state.len() == entries.len(),
                    "state len {} != manifest {}", state.len(),
                    entries.len());
    let mut store = ParamStore::new();
    for (lit, e) in state.iter().zip(entries) {
        store.push(&e.name, literal_to_host(lit, &e.shape, e.dtype)?);
    }
    Ok(store)
}

/// Convert a ParamStore back into state literals in manifest order.
pub fn store_to_state(store: &ParamStore, entries: &[StateEntry])
                      -> Result<Vec<xla::Literal>> {
    entries
        .iter()
        .map(|e| {
            let t = store
                .get(&e.name)
                .ok_or_else(|| anyhow::anyhow!("store missing {}", e.name))?;
            anyhow::ensure!(t.dims == e.shape, "{}: shape {:?} vs {:?}",
                            e.name, t.dims, e.shape);
            host_to_literal(t)
        })
        .collect()
}
