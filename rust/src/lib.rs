//! # lutq — Look-Up Table Quantization (LUT-Q)
//!
//! Production-grade reproduction of *"Iteratively Training Look-Up Tables
//! for Network Quantization"* (Cardinaux, Uhlich, Yoshiyama et al., 2018)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): fused k-means
//!   assign+reduce, one-hot LUT gather, pow-2 rounding, uniform fake-quant,
//!   multiplier-less BN, and the K-multiplication LUT matmul.
//! * **L2** — JAX model + the full per-minibatch LUT-Q algorithm (paper
//!   Table 1), AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L3** — this crate: PJRT runtime ([`runtime`]), training
//!   orchestrator ([`coordinator`]), data pipeline ([`data`]), quantization
//!   accounting ([`quant`]), quantized export ([`params`]), a pure-Rust
//!   multiplier-less **plan/execute inference engine** ([`infer`]) and the
//!   **serving layer** ([`serve`]) on top of it: the manifest graph is
//!   compiled once into an [`infer::Plan`] (validated ops, pre-unpacked
//!   LUT assignments, pre-rounded shift dictionaries, SAME-pad geometry,
//!   arena sizing); a [`serve::Registry`] shares one plan per model across
//!   a [`serve::Server`] worker pool whose [`serve::Batcher`] coalesces
//!   single-image requests into dynamic batches, executed batch-parallel
//!   and allocation-free from per-(model, worker) [`infer::Scratch`]
//!   arenas. A dependency-free HTTP front ([`serve::HttpFront`]) with
//!   deadline-aware admission control ([`serve::Admission`]) makes the
//!   stack network-reachable (`lutq serve`; API in README.md).
//!
//! Python never runs at training/serving time: `make artifacts` AOT-lowers
//! everything once; the `lutq` binary drives compiled HLO via PJRT and
//! serves exported models through the serve stack (`lutq infer`,
//! `lutq serve` — the HTTP front — and `lutq serve-bench`, which compares
//! the direct plan loop against the coalescing Server path in-process and
//! over HTTP, single- and multi-model).
//!
//! ## Quickstart
//! ```bash
//! make artifacts                 # AOT-lower the core artifact set
//! cargo run --release --example quickstart
//! cargo run --release --bin lutq -- train --artifact cifar_lutq4 --steps 300
//! cargo run --release --bin lutq -- serve-bench --artifact cifar_lutq4 \
//!     --model model.bin --batch 8 --json reports/BENCH_serve.json
//! # no artifacts? bench the built-in synthetic models (multi-model mode):
//! cargo run --release --bin lutq -- serve-bench --artifact synthetic
//! ```
//!
//! The PJRT bindings are vendored as a stub in offline builds (see
//! `rust/xla-stub/`); everything except `train`/`eval`/`export` runs
//! without the native XLA extension.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod detect;
pub mod infer;
pub mod jsonic;
pub mod params;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod testkit;
pub mod util;

pub use config::TrainConfig;
pub use coordinator::{LrSchedule, TrainResult, Trainer};
pub use runtime::Runtime;

use std::path::PathBuf;

/// Default artifacts directory: $LUTQ_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("LUTQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Default reports directory.
pub fn reports_dir() -> PathBuf {
    PathBuf::from("reports")
}
