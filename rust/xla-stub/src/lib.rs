//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The lutq runtime layer (`rust/src/runtime/`) was written against the
//! xla-rs API: `PjRtClient` / `PjRtLoadedExecutable` for execution and
//! `Literal` for host<->device tensors. The native XLA extension is not
//! available in the offline build environment, so this crate keeps the
//! same surface compiling:
//!
//! * **Host-side `Literal` operations are real** — construction from
//!   shape + bytes, scalar literals, element access and `to_vec` round
//!   trips work exactly, so literal-packing code and its tests behave.
//! * **Device operations are unavailable** — `PjRtClient::cpu()`,
//!   compilation and execution return a descriptive [`Error`]. Callers
//!   already treat runtime construction as fallible and skip
//!   artifact-dependent tests/benches when it fails.
//!
//! Replacing this path dependency with a real xla-rs build re-enables the
//! PJRT runtime with no source change in lutq.

use std::borrow::Borrow;

/// Error type matching how lutq consumes xla-rs errors (`{e:?}` and
/// `anyhow::Context`, which needs `std::error::Error + Send + Sync`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str =
    "xla backend unavailable: this build uses the vendored stub (see \
     rust/xla-stub); PJRT execution requires a real xla-rs build";

/// Element types used by the lutq artifact contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// Conversion trait for the typed `Literal` accessors.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_le_bytes4(self) -> [u8; 4];
    fn from_le_bytes4(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_le_bytes4(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_le_bytes4(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// Host tensor literal: shape + element type + little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType, shape: &[usize], bytes: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if elems * ty.byte_width() != bytes.len() {
            return Err(Error::new(format!(
                "literal shape {shape:?} needs {} bytes, got {}",
                elems * ty.byte_width(),
                bytes.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: bytes.to_vec() })
    }

    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal {
            ty: T::TY,
            shape: Vec::new(),
            bytes: x.to_le_bytes4().to_vec(),
        }
    }

    pub fn element_count(&self) -> usize {
        self.bytes.len() / self.ty.byte_width()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if T::TY != self.ty {
            return Err(Error::new("element type mismatch"));
        }
        if self.bytes.len() < 4 {
            return Err(Error::new("empty literal"));
        }
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.bytes[..4]);
        Ok(T::from_le_bytes4(b))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error::new("element type mismatch"));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| {
                let mut b = [0u8; 4];
                b.copy_from_slice(c);
                T::from_le_bytes4(b)
            })
            .collect())
    }

    /// Decompose a tuple literal. The stub never produces tuples (only
    /// execution does), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new(STUB_MSG))
    }
}

/// Parsed HLO module handle (stub: parsing requires the native library).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::new(STUB_MSG))
    }
}

/// An XLA computation built from an HLO module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT CPU client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(STUB_MSG))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self, _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }
}

/// Compiled executable handle (unreachable through the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self, _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

/// Device buffer handle (unreachable through the stub client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> =
            data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_shape_checks() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4]
        )
        .is_err());
    }

    #[test]
    fn device_paths_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
