//! Perf bench: PJRT runtime hot-path latency — artifact compile time,
//! literal packing, train_step / eval_step / infer execution. Feeds
//! EXPERIMENTS.md §Perf (L3 side).

mod common;

use lutq::runtime::{self};
use lutq::util::timer::bench;
use lutq::{TrainConfig, Trainer};

fn main() {
    let rt = common::runtime_or_skip();
    common::hr("runtime_exec — PJRT execution latency");

    for artifact in ["quickstart_mlp", "cifar_lutq4", "cifar_fp32"] {
        if !common::have_artifact(&rt, artifact) {
            continue;
        }
        let t = lutq::util::Timer::start();
        let man = rt.manifest(artifact).expect("manifest");
        let compile_first = {
            let _p = rt.load_program(&man, "train_step").expect("load");
            t.elapsed_ms()
        };
        // cache hit
        let t2 = lutq::util::Timer::start();
        let _p = rt.load_program(&man, "train_step").expect("load");
        let compile_cached = t2.elapsed_ms();

        let trainer =
            Trainer::new(&rt, TrainConfig::new(artifact).steps(1)
                .data_lens(256, 64))
                .expect("trainer");
        let ds = trainer.train_dataset();
        let mut batcher =
            lutq::data::Batcher::new(ds.as_ref(), man.batch_size, 0, true);
        let batch = batcher.next_batch();

        // literal packing latency
        let spec_shape = {
            let p = rt.load_program(&man, "train_step").unwrap();
            p.spec.inputs[0].shape.clone()
        };
        let pack = bench(3, 30, || {
            let _ = runtime::literal_f32(&spec_shape, &batch.x).unwrap();
        });

        // full step latency (state round-trip included — the L3 hot path)
        let mut state = trainer.init_state().expect("init");
        let step = bench(2, 10, || {
            let (_, ns) = trainer.step(0, &batch, &state).expect("step");
            state = ns;
        });

        let eval = bench(1, 5, || {
            let _ = trainer.evaluate(&state).unwrap();
        });

        println!(
            "{artifact:<16} compile {compile_first:>8.1} ms (cached \
             {compile_cached:.2} ms) | x-pack {pack} | step {step} | \
             eval {eval}"
        );
    }
}
