//! Regenerates paper Fig. 2 (experiment F2): validation-error INCREASE
//! over the fp32 baseline vs pruning fraction, for several quantization
//! bitwidths, with LUT-Q's zero-pinned dictionary entry.
//!
//! Paper claim: "we can prune the network up to 70% and quantize to 2-bit
//! without significant loss" — the 2-bit curve stays flat to ~70% then
//! climbs steeply at 90%.

mod common;

use lutq::params::export::QuantizedModel;
use lutq::report::{self, Series};
use lutq::{Runtime, TrainConfig, Trainer};

fn run(rt: &Runtime, artifact: &str, prune: f32, steps: usize)
       -> (f32, f32) {
    let mut cfg = TrainConfig::new(artifact)
        .steps(steps)
        .seed(4)
        .data_lens(8192, 1024);
    if prune > 0.0 {
        cfg = cfg.prune(prune);
    }
    let trainer = Trainer::new(rt, cfg).expect("trainer");
    let res = trainer.run().expect("train");
    let model = if res.manifest.quant_method() == "lutq" {
        let m = QuantizedModel::from_state(&res.state,
                                           &res.manifest.qlayers);
        let total: f32 = m.lut_layers.iter().map(|l| l.n() as f32).sum();
        m.lut_layers
            .iter()
            .map(|l| l.sparsity() * l.n() as f32)
            .sum::<f32>()
            / total
    } else {
        0.0
    };
    (res.eval_error, model)
}

fn main() {
    let steps = common::steps_or(250);
    let rt = common::runtime_or_skip();
    common::hr(&format!(
        "F2 — error increase vs pruning (paper Fig. 2) | {steps} steps/run"
    ));

    // fp32 baseline
    if !common::have_artifact(&rt, "cifar_fp32") {
        return;
    }
    let (base_err, _) = run(&rt, "cifar_fp32", 0.0, steps);
    println!("fp32 baseline error: {:.2}%\n", base_err * 100.0);

    let prunes = [0.0f32, 0.3, 0.5, 0.7, 0.9];
    let mut series: Vec<Series> = Vec::new();
    println!("| bits | prune target | val err | err increase | measured sparsity |");
    println!("|---|---|---|---|---|");
    for (bits, artifact) in
        [(2, "cifar_prune2"), (4, "cifar_prune4"), (8, "cifar_prune8")]
    {
        if !common::have_artifact(&rt, artifact) {
            continue;
        }
        let mut points = Vec::new();
        for &p in &prunes {
            let (err, sparsity) = run(&rt, artifact, p, steps);
            let incr = (err - base_err) * 100.0;
            println!(
                "| {bits} | {:.0}% | {:.2}% | {incr:+.2}% | {:.1}% |",
                p * 100.0,
                err * 100.0,
                sparsity * 100.0
            );
            points.push((p * 100.0, incr));
        }
        series.push(Series { label: format!("{bits}-bit"), points });
    }

    let plot = report::series_to_ascii(
        "Fig 2 (scaled): val-error increase vs pruning %",
        "prune %", "err increase (pp)", &series, 60, 14);
    println!("\n{plot}");
    println!("paper shape: flat to ~70% pruning at 2-bit, steep rise by 90%");
    let csv = report::series_to_csv("prune_pct", &series);
    let _ = report::write_report(&lutq::reports_dir(), "fig2_pruning.csv",
                                 &csv);
}
