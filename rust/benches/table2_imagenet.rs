//! Regenerates paper Table 2 (experiment T2): the ImageNet quantization
//! comparison — LUT-Q pow-2 vs INQ [24] vs apprentice-style uniform QAT
//! [15] across three model capacities and {4, 2}-bit weights with
//! {32, 8}-bit activations, quasi vs fully multiplier-less.
//!
//! Scaled substitution (DESIGN.md §2): ResNet-18/34/50 -> resnet-s/m/l
//! (depth 8/14/20) on a 20-class synthetic task. The reproduced quantity
//! is the ORDERING: LUT-Q matches or beats the fixed-grid baselines at
//! equal bitwidth; accuracy degrades with fewer bits; larger models
//! tolerate quantization better; fully multiplier-less costs extra error.

mod common;

use lutq::coordinator::sweep::Sweep;
use lutq::TrainConfig;

fn main() {
    let steps = common::steps_or(200);
    let rt = common::runtime_or_skip();
    common::hr(&format!(
        "T2 — ImageNet-style quant comparison (paper Table 2) | \
         {steps} steps/run"
    ));

    // (label, artifact suffix, needs_inq_schedule)
    let methods: &[(&str, &str, bool)] = &[
        ("fp32 32/32", "fp32", false),
        ("INQ 5-bit pow2 w / 32-bit act", "inq5", true),
        ("INQ 4-bit pow2 w / 32-bit act", "inq4", true),
        ("uniform(apprentice) 4-bit w / 8-bit act", "uniform4", false),
        ("LUT-Q pow2 4-bit w / 8-bit act (quasi)", "lutq4", false),
        ("LUT-Q pow2 4-bit w / 8-bit act (FULLY)", "lutq4_ml", false),
        ("INQ 2-bit pow2 w / 32-bit act", "inq2", true),
        ("uniform(apprentice) 2-bit w / 8-bit act", "uniform2", false),
        ("LUT-Q pow2 2-bit w / 8-bit act (quasi)", "lutq2", false),
        ("LUT-Q pow2 2-bit w / 8-bit act (FULLY)", "lutq2_ml", false),
        ("BinaryConnect {-a,a}", "bc", false),
        ("TWN {-a,0,a}", "twn", false),
    ];
    let sizes = [("resnet-s (ResNet-18 analog)", "s"),
                 ("resnet-m (ResNet-34 analog)", "m"),
                 ("resnet-l (ResNet-50 analog)", "l")];

    // one sweep table per model size, mirroring Table 2's columns
    for (size_label, sz) in sizes {
        let mut sweep = Sweep::new(&rt);
        for (label, suffix, inq) in methods {
            let artifact = format!("imnet_{sz}_{suffix}");
            if !common::have_artifact(&rt, &artifact) {
                continue;
            }
            let mut cfg = TrainConfig::new(&artifact)
                .steps(steps)
                .seed(2)
                .data_lens(8192, 1024);
            if *inq {
                cfg = cfg.inq_standard();
            }
            sweep.run(label, cfg).expect("train");
        }
        let md = sweep.to_markdown(&format!("T2 — {size_label}"));
        println!("{md}");
        let _ = lutq::report::write_report(
            &lutq::reports_dir(),
            &format!("table2_{sz}.md"),
            &md,
        );
    }
    println!(
        "paper reference (Table 2, ResNet-18/34/50 top-1 err):\n\
         \x20 4-bit: LUT-Q 31.6/28.1/25.5 <= apprentice 33.6/29.7/28.5, \
         INQ(5b) 31.0/-/25.2\n\
         \x20 2-bit: LUT-Q 35.8/30.5/26.9 vs apprentice 33.9/30.8/29.2 \
         (LUT-Q wins except ResNet-18)\n\
         \x20 fully mult-less costs extra error, shrinking with model size"
    );
}
