//! Shared helpers for the bench harnesses (criterion is unavailable
//! offline; rust/src/util/timer.rs provides the measurement core).
//!
//! Conventions:
//!   LUTQ_BENCH_STEPS  override training steps per run (default per-bench)
//!   LUTQ_BENCH_FULL=1 paper-scale settings (longer runs)
//! Each bench prints the regenerated paper table/figure to stdout and
//! writes CSV/markdown into reports/.

use lutq::runtime::Runtime;

pub fn steps_or(default: usize) -> usize {
    std::env::var("LUTQ_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full() { default * 4 } else { default })
}

pub fn full() -> bool {
    std::env::var("LUTQ_BENCH_FULL").as_deref() == Ok("1")
}

/// Open the runtime; exits 0 with a notice if artifacts are missing so
/// `cargo bench` stays green before `make artifacts`.
pub fn runtime_or_skip() -> Runtime {
    let dir = lutq::artifacts_dir();
    if !dir.exists() {
        println!("SKIP: no artifacts under {} — run `make artifacts`",
                 dir.display());
        std::process::exit(0);
    }
    Runtime::new(&dir).expect("create PJRT runtime")
}

/// Check a specific artifact exists; returns false (with a notice) if not.
pub fn have_artifact(rt: &Runtime, name: &str) -> bool {
    let ok = rt.artifacts_root().join(name).join("manifest.json").exists();
    if !ok {
        println!("SKIP {name}: artifact missing (make artifacts-all)");
    }
    ok
}

pub fn hr(title: &str) {
    println!("\n{}", "=".repeat(72));
    println!("{title}");
    println!("{}", "=".repeat(72));
}
