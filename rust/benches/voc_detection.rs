//! Regenerates the paper's §2 Pascal VOC detection result (experiment
//! VOC): memory footprint vs mAP for the YOLO-style detector under LUT-Q.
//!
//! Paper: YOLOv2 200 MB @ 72% mAP -> 10 MB @ >70% (8-bit LUT-Q +
//! architecture changes) -> 1.72 MB @ ~64% (4-bit). Scaled substitution:
//! tiny_yolo on SyntheticShapes; the footprint arithmetic is exact, the
//! mAP-vs-bits tradeoff is reproduced in shape.

mod common;

use lutq::data::{Batcher, Slice, SyntheticShapes};
use lutq::detect::{decode_yolo, mean_average_precision, nms, ImageEval};
use lutq::params::export::QuantizedModel;
use lutq::runtime::{self, Runtime};
use lutq::util::human_bytes;
use lutq::{TrainConfig, Trainer};

fn evaluate_map(rt: &Runtime, trainer: &Trainer, res: &lutq::TrainResult)
                -> f32 {
    let man = &res.manifest;
    let infer = rt.load_program(man, "infer").expect("infer");
    let grid = man.meta.grid;
    let ncls = man.meta.num_classes;
    let full = SyntheticShapes::with_dims(
        trainer.cfg.train_len + trainer.cfg.eval_len,
        trainer.cfg.seed, man.meta.input[0], grid, ncls);
    let offset = trainer.eval_offset();
    let eval = Slice::new(std::sync::Arc::new(full.clone()), offset,
                          trainer.cfg.eval_len);
    let batch_size = infer.spec.inputs[0].shape[0];
    let mut images = Vec::new();
    for (batch, valid) in Batcher::eval_batches(&eval, batch_size) {
        let x = runtime::literal_f32(&infer.spec.inputs[0].shape, &batch.x)
            .unwrap();
        let mut args = vec![x];
        for e in &man.state {
            args.push(
                runtime::host_to_literal(res.state.get(&e.name).unwrap())
                    .unwrap(),
            );
        }
        let out = infer.run(&args).expect("infer run");
        let pred = out.f32_vec(0).unwrap();
        let per = grid * grid * (5 + ncls);
        for (j, &idx) in batch.indices.iter().take(valid).enumerate() {
            let dets = nms(
                decode_yolo(&pred[j * per..(j + 1) * per], grid, ncls, 0.5),
                0.45,
            );
            images.push(ImageEval {
                dets,
                gts: full.ground_truth(idx + offset),
            });
        }
    }
    mean_average_precision(&images, ncls, 0.5)
}

fn main() {
    let steps = common::steps_or(400);
    let rt = common::runtime_or_skip();
    common::hr(&format!(
        "VOC — detection footprint vs mAP (paper §2) | {steps} steps/run"
    ));

    let mut rows = Vec::new();
    let mut fp32_bytes = 0u64;
    for (label, artifact) in [
        ("fp32 YOLO-analog", "voc_fp32"),
        ("LUT-Q 8-bit", "voc_lutq8"),
        ("LUT-Q 4-bit", "voc_lutq4"),
    ] {
        if !common::have_artifact(&rt, artifact) {
            continue;
        }
        let cfg = TrainConfig::new(artifact)
            .steps(steps)
            .seed(5)
            .data_lens(4096, 256);
        let trainer = Trainer::new(&rt, cfg).expect("trainer");
        let res = trainer.run().expect("train");
        let map = evaluate_map(&rt, &trainer, &res);
        let stored = if res.manifest.quant_method() == "lutq" {
            QuantizedModel::from_state(&res.state, &res.manifest.qlayers)
                .stored_bytes()
        } else {
            let b = res.manifest.param_count() * 4;
            fp32_bytes = b;
            b
        };
        rows.push((label, map, stored));
    }

    let mut md = String::from(
        "\n| model | mAP@0.5 | weights stored | reduction |\n|---|---|---|---|\n");
    for (label, map, stored) in &rows {
        md.push_str(&format!(
            "| {label} | {:.1}% | {} | {:.1}x |\n",
            map * 100.0,
            human_bytes(*stored),
            fp32_bytes as f64 / *stored as f64
        ));
    }
    println!("{md}");
    println!("paper reference: 200 MB @ 72% -> 10 MB @ >70% (8-bit) -> \
              1.72 MB @ ~64% (4-bit): large footprint cuts at modest mAP \
              cost, growing at 4-bit");
    let _ = lutq::report::write_report(&lutq::reports_dir(),
                                       "voc_detection.md", &md);
}
