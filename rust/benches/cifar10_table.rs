//! Regenerates the paper's §2 CIFAR-10 results (experiment C10 in
//! DESIGN.md): full-precision ResNet vs LUT-Q pow-2 at 4/2-bit, quasi
//! (standard BN) vs fully (ML-BN) multiplier-less.
//!
//! Paper (CIFAR-10, ResNet-20): fp32 7.4% | quasi 4-bit 7.6% | quasi
//! 2-bit 8.0% | fully 4-bit 8.1% | fully 2-bit 9.0%. We reproduce the
//! ORDERING on the synthetic stand-in at reduced scale, not the absolute
//! numbers (see DESIGN.md §2/§7).

mod common;

use lutq::coordinator::sweep::Sweep;
use lutq::params::export::QuantizedModel;
use lutq::util::human_bytes;
use lutq::TrainConfig;

fn main() {
    let steps = common::steps_or(300);
    let rt = common::runtime_or_skip();
    common::hr(&format!(
        "C10 — CIFAR-10 quant table (paper §2 text) | {steps} steps/run"
    ));

    let runs = [
        ("fp32 (unconstrained)", "cifar_fp32"),
        ("LUT-Q 4-bit pow2, quasi mult-less", "cifar_lutq4"),
        ("LUT-Q 2-bit pow2, quasi mult-less", "cifar_lutq2"),
        ("LUT-Q 4-bit pow2, FULLY mult-less", "cifar_lutq4_ml"),
        ("LUT-Q 2-bit pow2, FULLY mult-less", "cifar_lutq2_ml"),
    ];
    let mut sweep = Sweep::new(&rt);
    for (label, artifact) in runs {
        if !common::have_artifact(&rt, artifact) {
            continue;
        }
        let cfg = TrainConfig::new(artifact)
            .steps(steps)
            .seed(1)
            .data_lens(8192, 1024);
        let res = sweep.run(label, cfg).expect("train");
        if res.manifest.quant_method() == "lutq" {
            let model = QuantizedModel::from_state(&res.state,
                                                   &res.manifest.qlayers);
            sweep.annotate_last("weights stored",
                                human_bytes(model.stored_bytes()));
            sweep.annotate_last("pow2 dict",
                                format!("{}", model.is_multiplierless()));
        } else {
            sweep.annotate_last(
                "weights stored",
                human_bytes(res.manifest.param_count() * 4),
            );
        }
    }
    let md = sweep.to_markdown("C10: CIFAR-10 (synthetic stand-in)");
    println!("{md}");
    println!("paper reference (real CIFAR-10, ResNet-20): fp32 7.4% < \
              quasi4 7.6% < quasi2 8.0% <= fully4 8.1% < fully2 9.0%");
    println!("expected reproduction: same ordering, error increases with \
              fewer bits and with ML-BN");
    let _ = lutq::report::write_report(&lutq::reports_dir(),
                                       "cifar10_table.md", &md);
}
