//! Regenerates the paper's §1/§2 memory and multiplication accounting
//! (experiment MEM) — these are exact formula evaluations, so the PAPER'S
//! OWN NUMBERS reproduce exactly (unlike the accuracy experiments, which
//! are scaled).
//!
//!   weights:  N*B_float  ->  K*B_float + N*ceil(log2 K) bits
//!   mults:    I per output accumulator -> K
//!
//! Includes the headline ResNet-50 claim: 2-bit weights + 8-bit
//! activations = 7.4 MB total vs 97.5 MB fp32, and "multiplications
//! reduced by two orders of magnitude".

mod common;

use lutq::quant::stats::{activation_bytes, CompressionStats, LayerShape};
use lutq::util::human_bytes;

/// Approximate conv-layer inventory of a standard ResNet (He et al. 2016).
/// (n_layers, k, cin, cout, out_hw) blocks at ImageNet geometry.
fn resnet50_layers() -> Vec<LayerShape> {
    let mut layers = Vec::new();
    let mut push = |n: usize, k: usize, cin: usize, cout: usize, hw: usize| {
        for i in 0..n {
            layers.push(LayerShape {
                name: format!("c{k}x{k}_{cin}_{cout}_{i}"),
                n: (k * k * cin * cout) as u64,
                fan_in: (k * k * cin) as u64,
                outputs: (hw * hw * cout) as u64,
            });
        }
    };
    // stem + bottleneck stages (1x1/3x3/1x1), ~25.5M params total
    push(1, 7, 3, 64, 112);
    for &(n, cin, mid, hw) in
        &[(3, 256, 64, 56), (4, 512, 128, 28), (6, 1024, 256, 14),
          (3, 2048, 512, 7)]
    {
        push(n, 1, cin, mid, hw);
        push(n, 3, mid, mid, hw);
        push(n, 1, mid, cin, hw);
    }
    push(1, 1, 2048, 1000, 1); // fc head as 1x1
    layers
}

fn table_row(name: &str, layers: &[LayerShape], bits: usize,
             act_bits: u64, act_elems: u64) {
    let k = 1usize << bits;
    let s = CompressionStats::compute(layers, k);
    let act = activation_bytes(&[act_elems], act_bits);
    println!(
        "| {name} | {bits}-bit (K={k}) | {} | {} | {} | {:.1}x | {:.0}x |",
        human_bytes(s.dense_bytes()),
        human_bytes(s.lutq_bytes()),
        human_bytes(s.lutq_bytes() + act),
        s.compression_ratio(),
        s.mult_reduction()
    );
}

fn main() {
    common::hr("MEM — paper §1 formulas (exact reproduction)");

    let r50 = resnet50_layers();
    let n: u64 = r50.iter().map(|l| l.n).sum();
    println!("ResNet-50 inventory: {} conv layers, {:.1}M weights \
              (paper: ~25.5M)\n",
             r50.len(), n as f64 / 1e6);

    // activation budget ~ largest activation tensors at batch 1, 8-bit
    // (paper counts params+activations = 7.4 MB total at 2-bit/8-bit)
    let act_elems: u64 = 112 * 112 * 64 + 56 * 56 * 256;

    println!("| net | quant | dense weights | LUT-Q weights | + 8b acts | \
              weight compression | mult reduction |");
    println!("|---|---|---|---|---|---|---|");
    for bits in [8, 5, 4, 2] {
        table_row("ResNet-50", &r50, bits, 8, act_elems);
    }

    let s2 = CompressionStats::compute(&r50, 4);
    println!(
        "\npaper headline check (ResNet-50, 2-bit weights + 8-bit acts):\n\
         \x20 dense fp32 weights: {} (paper: 97.5 MB params+acts)\n\
         \x20 LUT-Q total:        {} (paper: 7.4 MB)\n\
         \x20 mult reduction:     {:.0}x (paper: two orders of magnitude)",
        human_bytes(s2.dense_bytes() + act_elems * 4),
        human_bytes(s2.lutq_bytes()
            + activation_bytes(&[act_elems], 8)),
        s2.mult_reduction()
    );

    // sanity: the measured packed exports obey the same formula
    common::hr("MEM — packed-export consistency (measured = formula)");
    let rt = common::runtime_or_skip();
    if common::have_artifact(&rt, "cifar_lutq4") {
        let man = rt.manifest("cifar_lutq4").expect("manifest");
        let k = man.dict_size();
        let lut_n: u64 = man
            .state
            .iter()
            .filter(|e| e.role == "assign")
            .map(|e| e.shape.iter().product::<usize>() as u64)
            .sum();
        let formula_bits = man.qlayers.len() as u64 * k as u64 * 32
            + lut_n * lutq::quant::bitpack::bits_for(k) as u64;
        println!(
            "cifar_lutq4: N={lut_n} tied weights, K={k} -> formula {} \
             (packed export adds only byte-rounding)",
            human_bytes(formula_bits / 8)
        );
    }
}
