//! Perf bench: host-side 1-D k-means (assignment + update) across weight
//! counts and K — the Rust mirror of the L1 kernel, used at export time.
//! Also prints the structural VMEM/MXU estimate for the Pallas kernel
//! (DESIGN.md §5): interpret-mode wallclock is NOT a TPU proxy, so the L1
//! perf model is analytic.

mod common;

use lutq::quant::kmeans::{assign, kmeans_1d, update};
use lutq::util::timer::bench;
use lutq::util::Rng;

fn main() {
    common::hr("kmeans — host-side Lloyd iteration throughput");
    println!("| N | K | assign ms | update ms | full-converge iters |");
    println!("|---|---|---|---|---|");
    for &n in &[10_000usize, 100_000, 1_000_000] {
        for &k in &[4usize, 16, 256] {
            let mut rng = Rng::new(3);
            let vals: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut centroids: Vec<f32> =
                (0..k).map(|i| -2.0 + 4.0 * i as f32 / k as f32).collect();
            let a = bench(1, 5, || {
                let _ = assign(&vals, &centroids);
            });
            let asg = assign(&vals, &centroids);
            let u = bench(1, 5, || {
                let mut c = centroids.clone();
                update(&vals, &asg, &mut c);
            });
            update(&vals, &asg, &mut centroids);
            let res = kmeans_1d(&vals, k, 50, &mut rng);
            println!(
                "| {n} | {k} | {:.2} | {:.2} | {} |",
                a.median_ms(),
                u.median_ms(),
                res.iterations
            );
        }
    }

    common::hr("L1 Pallas kmeans_step — structural TPU estimate (§5)");
    // (N, K) -> tiles of 1024, VMEM per tile, MXU ops via one-hot matmuls
    for &(n, k) in &[(36_864usize, 16usize), (589_824, 16), (36_864, 4)] {
        let tiles = n.div_ceil(1024);
        let vmem_tile = 1024 * 4 /* w */ + 1024 * 4 /* mask */
            + k * 4 * 3 /* dict + sums + counts */
            + 1024 * k * 4 /* onehot transient */;
        let mxu_flops_per_tile = 2 * 1024 * k * 2; // two (1024,K) matmuls
        let hbm_bytes = n * 8; // w + mask streamed
        let ai = (tiles * mxu_flops_per_tile) as f64 / hbm_bytes as f64;
        println!(
            "N={n:<7} K={k:<3}: {tiles:>4} tiles, {:>7} B VMEM/tile, \
             {:>5.1} FLOP/B arithmetic intensity (memory-bound reduce)",
            vmem_tile, ai
        );
    }
}
