//! Perf bench: synthetic data pipeline — render cost per image, batcher
//! throughput, and prefetcher scaling over worker counts. L3 must keep the
//! PJRT step fed: pipeline throughput should exceed 1/step-time.

mod common;

use std::sync::Arc;

use lutq::data::{Batcher, Dataset, Prefetcher, SyntheticImages,
                 SyntheticShapes};
use lutq::util::timer::{bench, Timer};
use lutq::util::Rng;

fn main() {
    common::hr("data_pipeline — render / batch / prefetch throughput");

    let ds = SyntheticImages::cifar(4096, 1).with_augment(true);
    let mut buf = vec![0f32; ds.input_elems()];
    let r = bench(5, 50, || {
        ds.render(123, &mut buf);
    });
    println!("render cifar32 image: {r}");

    let det = SyntheticShapes::new(4096, 1);
    let mut dbuf = vec![0f32; det.input_elems()];
    let rd = bench(5, 50, || {
        det.render(7, &mut dbuf);
    });
    println!("render detection image: {rd}");

    // synchronous batcher
    let mut batcher = Batcher::new(&ds, 64, 0, true);
    let b = bench(2, 20, || {
        let _ = batcher.next_batch();
    });
    println!("sync batcher (b=64): {b}  -> {:.1} img/s",
             64.0 / (b.median_ns as f64 / 1e9));

    // prefetcher scaling
    for workers in [1usize, 2, 4] {
        let ds = Arc::new(SyntheticImages::cifar(4096, 1)
            .with_augment(true));
        let mut pf = Prefetcher::new(ds, 64, 0, workers, 4);
        // warm
        for _ in 0..3 {
            let _ = pf.next_batch();
        }
        let t = Timer::start();
        let n = 30;
        for _ in 0..n {
            let _ = pf.next_batch();
        }
        let s = t.elapsed_s();
        println!(
            "prefetcher {workers} workers: {:.1} ms/batch -> {:.0} img/s",
            s / n as f64 * 1e3,
            (n * 64) as f64 / s
        );
    }

    // reference: the training step consumes ~1 batch / 180 ms on the cifar
    // artifact, i.e. needs ~355 img/s — confirm the pipeline exceeds it.
    let mut rng = Rng::new(0);
    std::hint::black_box(rng.next_u64());
}
