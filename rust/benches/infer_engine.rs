//! Perf bench: plan/execute inference engine + the serving layer.
//!
//! Four questions, answered with p50/p99/p99.9 latency and images/sec:
//!   1. What does compile-once buy over the legacy compile-per-call path
//!      (graph re-lowered, assignments re-unpacked every request)?
//!   2. What does batch parallelism add on top?
//!   3. What do the SIMD inner kernels buy over the scalar reference
//!      backend, and the integer product-LUT kernels over SIMD
//!      (LUT-trick and dense modes, same compiled model)?
//!   4. What does dynamic batch coalescing (`serve::Server`) buy over a
//!      naive one-image-at-a-time serving loop?
//!
//! Also regenerates the dense vs LUT-trick vs shift-only op-count table
//! that motivates the kernels. Writes reports/BENCH_infer_plan.json so
//! the perf trajectory is tracked across PRs; the `perf-gate` CI job
//! feeds that file to `lutq bench-check` against the committed
//! reports/BENCH_baseline.json (row labels are machine-independent:
//! multi-core rows use `mt`/`mw`, not the host's core count). Feeds
//! EXPERIMENTS.md §Perf.

mod common;

use std::sync::Arc;
use std::time::Duration;

use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::report::{latency_reports_json, write_report, LatencyReport};
use lutq::serve::{Registry, Server, ServerConfig};
use lutq::testkit::models::synth_conv_model;
use lutq::util::{Rng, Timer};

fn popts(mode: ExecMode, threads: usize) -> PlanOptions {
    PlanOptions { mode, act_bits: 8, mlbn: mode == ExecMode::ShiftOnly,
                  threads, ..PlanOptions::default() }
}

/// Batch-invariant plan options for the serving comparison (per-tensor
/// act-quant would cap coalescing at batch 1).
fn serve_opts(threads: usize) -> PlanOptions {
    PlanOptions { mode: ExecMode::LutTrick, act_bits: 0, mlbn: false,
                  threads, ..PlanOptions::default() }
}

/// Per-request latencies (ms) + total wall seconds for `iters` calls.
fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F)
                       -> (Vec<f32>, f64) {
    for _ in 0..warmup {
        f();
    }
    let wall = Timer::start();
    let mut lat = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        lat.push(t.elapsed_ms() as f32);
    }
    (lat, wall.elapsed_s())
}

fn main() {
    common::hr("infer_engine — plan/execute vs legacy compile-per-call");
    let batch = 8usize;
    let iters = common::steps_or(12);
    let mut rng = Rng::new(1);
    let x = Tensor::new(vec![batch, 32, 32, 3],
                        rng.normals(batch * 32 * 32 * 3));
    let (graph, model) = synth_conv_model(4, false);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // sanity: compile-once output is bit-identical to compile-per-call
    let p1 = Plan::compile(&graph, &model, popts(ExecMode::LutTrick, 1),
                           &[32, 32, 3])
        .expect("compile");
    let mut s1 = p1.scratch();
    let (y_once, c_once) = p1.run(&x, &mut s1).expect("run");
    {
        let p = Plan::compile(&graph, &model,
                              popts(ExecMode::LutTrick, 1), &[32, 32, 3])
            .expect("compile");
        let mut s = p.scratch();
        let (y_fresh, c_fresh) = p.run(&x, &mut s).expect("run");
        assert_eq!(y_once.data, y_fresh.data);
        assert_eq!(c_once, c_fresh);
    }

    let mut rows: Vec<LatencyReport> = Vec::new();

    // legacy path: re-lower graph + re-resolve weights on every request
    let (lat, total) = measure(1, iters, || {
        let p = Plan::compile(&graph, &model,
                              popts(ExecMode::LutTrick, 1), &[32, 32, 3])
            .expect("compile");
        let mut s = p.scratch();
        p.run_into(&x, &mut s).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        "lut4/compile-per-call/1t", batch, 1, true, &lat, total)
        .with_model("synth_lut4")
        .with_backend(p1.backend_name())
        .with_transport("direct"));

    // compiled plan, single thread
    let (lat, total) = measure(2, iters, || {
        p1.run_into(&x, &mut s1).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        "lut4/compile-once/1t", batch, 1, false, &lat, total)
        .with_model("synth_lut4")
        .with_backend(p1.backend_name())
        .with_transport("direct"));

    // compiled plan, batch-parallel ("mt" keeps the row label stable
    // across hosts with different core counts for the perf gate)
    let pn = Plan::compile(&graph, &model, popts(ExecMode::LutTrick, 0),
                           &[32, 32, 3])
        .expect("compile");
    let mut sn = pn.scratch();
    let (lat, total) = measure(2, iters, || {
        pn.run_into(&x, &mut sn).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        "lut4/compile-once/mt", batch, cores, false, &lat, total)
        .with_model("synth_lut4")
        .with_backend(pn.backend_name())
        .with_transport("direct"));

    println!("| path | p50 ms | p99 ms | images/s |");
    println!("|---|---|---|---|");
    for r in &rows {
        println!("| {} | {:.2} | {:.2} | {:.1} |", r.label, r.p50_ms,
                 r.p99_ms, r.images_per_sec);
    }
    let speedup = rows[0].p50_ms / rows[1].p50_ms.max(1e-6);
    println!("\ncompile-once single-thread speedup vs compile-per-call: \
              {speedup:.2}x (target >= 3x at batch {batch})");

    // ------- kernel backends: scalar vs simd vs int, same model
    // (int-scalar rides along so the vectorized-int win is measured
    // against its own pinned reference, not just the float backends)
    common::hr("kernel backends — scalar vs simd vs int-scalar vs int \
                (LUTQ_KERNEL A/B)");
    for (mode, mtag) in [(ExecMode::LutTrick, "lut4"),
                         (ExecMode::Dense, "dense4")] {
        let mut ips = [0f64; 4];
        for (ki, (choice, ktag)) in
            [(KernelBackend::Scalar, "scalar"),
             (KernelBackend::Simd, "simd"),
             (KernelBackend::IntScalar, "int-scalar"),
             (KernelBackend::Int, "int")].into_iter().enumerate()
        {
            let p = Plan::compile(
                &graph, &model,
                PlanOptions { mode, act_bits: 8, mlbn: false, threads: 1,
                              kernel: choice },
                &[32, 32, 3])
                .expect("compile");
            let mut s = p.scratch_for(batch);
            let (lat, total) = measure(2, iters, || {
                p.run_into(&x, &mut s).expect("run");
            });
            let row = LatencyReport::from_latencies(
                format!("{mtag}/kernel-{ktag}/1t"), batch, 1, false,
                &lat, total)
                .with_model("synth_lut4")
                .with_backend(p.backend_name())
                .with_transport("direct")
                .with_table_bytes(p.int_table_bytes());
            println!("| {} [{}] | {:.2} | {:.2} | {:.1} | {} B |",
                     row.label, row.backend, row.p50_ms, row.p99_ms,
                     row.images_per_sec, row.int_table_bytes);
            ips[ki] = row.images_per_sec;
            rows.push(row);
        }
        println!(
            "{mtag}: simd {:.1} images/s vs scalar {:.1} ({:.2}x; \
             acceptance target >= 1.5x on AVX2 hosts)",
            ips[1], ips[0], ips[1] / ips[0].max(1e-9)
        );
        println!(
            "{mtag}: int {:.1} images/s vs int-scalar {:.1} ({:.2}x; \
             acceptance target >= 1.5x on AVX2 hosts — the vectorized \
             integer kernels vs their pinned reference)",
            ips[3], ips[2], ips[3] / ips[2].max(1e-9)
        );
        println!(
            "{mtag}: int {:.1} images/s vs simd {:.1} ({:.2}x; \
             acceptance target >= 1x — the multiplier-less path should \
             not cost throughput)",
            ips[3], ips[1], ips[3] / ips[1].max(1e-9)
        );
    }

    // --------------------------- coalescing vs naive single-image loop
    common::hr("serve — dynamic coalescing vs naive one-image loop");
    let n_imgs = batch * iters;
    let pool: Vec<Vec<f32>> = {
        let mut r = Rng::new(9);
        (0..16).map(|_| r.normals(32 * 32 * 3)).collect()
    };

    // naive serving: every request is its own batch-1 run, one thread
    let p_naive = Plan::compile(&graph, &model, serve_opts(1),
                                &[32, 32, 3])
        .expect("compile");
    let mut s_naive = p_naive.scratch_for(1);
    let mut img = 0usize;
    let (lat, total) = measure(2, n_imgs, || {
        let x1 = Tensor::new(vec![1, 32, 32, 3],
                             pool[img % pool.len()].clone());
        img += 1;
        p_naive.run_into(&x1, &mut s_naive).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        "lut4/naive-batch1/1t", 1, 1, false, &lat, total)
        .with_model("synth_lut4")
        .with_backend(p_naive.backend_name())
        .with_transport("direct"));

    // coalesced serving: worker pool + dynamic batching up to `batch`
    let mut registry = Registry::new();
    registry
        .register("synth_lut4",
                  Plan::compile(&graph, &model, serve_opts(1),
                                &[32, 32, 3]).expect("compile"))
        .expect("register");
    let server = Server::start(registry, ServerConfig {
        workers: cores,
        max_batch: batch,
        linger: Duration::from_millis(1),
        queue_cap: 4096,
        ..Default::default()
    })
    .expect("server");
    let server = Arc::new(server);
    // closed-loop clients bound the coalesced batch size, so keep at
    // least 2x the cap in flight
    let clients = (2 * cores).max(2 * batch);
    let pools: lutq::serve::load::SamplePools = Arc::new(vec![pool]);
    let (lat, served_total) =
        lutq::serve::load::closed_loop(&server, &[0], &pools, n_imgs,
                                       clients)
            .expect("serve load");
    let served_lat: Vec<f32> = lat.iter().map(|(_, v)| *v).collect();
    let server = Arc::try_unwrap(server)
        .ok()
        .expect("clients joined");
    let reports = server.shutdown();
    rows.push(LatencyReport::from_latencies(
        "lut4/served-coalesced/mw", 1, cores, false, &served_lat,
        served_total)
        .with_model("synth_lut4")
        .with_backend(reports[0].backend.clone())
        .with_transport("inproc"));

    let naive = &rows[rows.len() - 2];
    let served = &rows[rows.len() - 1];
    println!("| path | p50 ms | p99.9 ms | images/s |");
    println!("|---|---|---|---|");
    for r in [naive, served] {
        println!("| {} | {:.2} | {:.2} | {:.1} |", r.label, r.p50_ms,
                 r.p999_ms, r.images_per_sec);
    }
    println!(
        "\ncoalescing throughput vs naive: {:.2}x (mean batch {:.2}, \
         max {}, {} batches for {} requests)",
        served.images_per_sec / naive.images_per_sec.max(1e-9),
        reports[0].mean_batch,
        reports[0].max_batch,
        reports[0].batches,
        reports[0].requests
    );
    assert_eq!(reports[0].requests as usize, n_imgs,
               "every request answered exactly once");

    // ------------------------------------------------- op-count table
    common::hr("op counts — dense vs LUT-trick vs shift-only");
    println!("| K | mode | median ms | mults | shifts | adds |");
    println!("|---|---|---|---|---|---|");
    let xt = Tensor::new(vec![4, 32, 32, 3],
                         Rng::new(3).normals(4 * 32 * 32 * 3));
    for k in [4usize, 16] {
        for (mode, pow2) in [(ExecMode::Dense, false),
                             (ExecMode::LutTrick, false),
                             (ExecMode::ShiftOnly, true)] {
            let (graph, model) = synth_conv_model(k, pow2);
            let plan = Plan::compile(&graph, &model, popts(mode, 1),
                                     &[32, 32, 3])
                .expect("compile");
            let mut s = plan.scratch();
            let counts = plan.run_into(&xt, &mut s).expect("run");
            let (lat, _) = measure(1, 5, || {
                plan.run_into(&xt, &mut s).expect("run");
            });
            println!(
                "| {k} | {mode:?} | {:.2} | {} | {} | {} |",
                lutq::util::stats::quantile(&lat, 0.5),
                counts.mults,
                counts.shifts,
                counts.adds
            );
            if mode == ExecMode::ShiftOnly {
                assert!(counts.is_multiplierless());
            }
        }
    }
    println!("\nexpected: LUT-trick mults = K per accumulator (vs fan-in \
              dense); shift-only executes 0 multiplies");

    let path = write_report(&lutq::reports_dir(), "BENCH_infer_plan.json",
                            &latency_reports_json(&rows))
        .expect("write report");
    println!("\nwrote {}", path.display());
}
