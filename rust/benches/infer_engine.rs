//! Perf bench: pure-Rust inference engine throughput in the three
//! execution modes (dense MAC vs LUT bucket trick vs shift-only), plus the
//! op-count ratios that motivate them. Feeds EXPERIMENTS.md §Perf.

mod common;

use lutq::infer::{Engine, EngineOptions, ExecMode, Tensor};
use lutq::params::export::{LutLayer, QuantizedModel};
use lutq::params::HostTensor;
use lutq::quant::bitpack::pack_assignments;
use lutq::util::timer::bench;
use lutq::util::Rng;

/// Build a synthetic 3-conv model directly (no training needed for perf).
fn synth_model(k: usize, pow2: bool) -> (lutq::jsonic::Json, QuantizedModel) {
    let graph = lutq::jsonic::parse(
        r#"[
        {"op":"conv","name":"c0","cin":3,"cout":16,"k":3,"stride":1},
        {"op":"bn","name":"b0","c":16},
        {"op":"relu"},
        {"op":"conv","name":"c1","cin":16,"cout":32,"k":3,"stride":2},
        {"op":"bn","name":"b1","c":32},
        {"op":"relu"},
        {"op":"gap"},
        {"op":"affine","name":"head","cin":32,"cout":10}
    ]"#,
    )
    .unwrap();
    let mut rng = Rng::new(7);
    let mut model = QuantizedModel::default();
    let dict: Vec<f32> = if pow2 {
        (0..k)
            .map(|i| {
                let e = (i as i32 % 8) - 4;
                let s = if i % 2 == 0 { 1.0 } else { -1.0 };
                s * (e as f32).exp2()
            })
            .collect()
    } else {
        (0..k).map(|_| rng.normal() * 0.2).collect()
    };
    for (name, n) in [("c0", 3 * 3 * 3 * 16), ("c1", 3 * 3 * 16 * 32),
                      ("head", 32 * 10)] {
        let assign: Vec<u32> = (0..n).map(|_| rng.below(k) as u32).collect();
        model.lut_layers.push(LutLayer {
            name: name.into(),
            packed: pack_assignments(&assign, k),
            dict: dict.clone(),
            shape: if name == "head" {
                vec![32, 10]
            } else if name == "c0" {
                vec![3, 3, 3, 16]
            } else {
                vec![3, 3, 16, 32]
            },
        });
    }
    for (name, c) in [("b0", 16), ("b1", 32)] {
        model.fp.insert(format!("{name}.gamma"),
                        HostTensor::f32(vec![c], vec![1.0; c]));
        model.fp.insert(format!("{name}.beta"),
                        HostTensor::f32(vec![c], vec![0.0; c]));
        model.fp.insert(format!("{name}.rmean"),
                        HostTensor::f32(vec![c], vec![0.0; c]));
        model.fp.insert(format!("{name}.rvar"),
                        HostTensor::f32(vec![c], vec![1.0; c]));
    }
    model.fp.insert("head.b".into(),
                    HostTensor::f32(vec![10], vec![0.0; 10]));
    (graph, model)
}

fn main() {
    common::hr("infer_engine — dense vs LUT-trick vs shift-only");
    let mut rng = Rng::new(1);
    let x = Tensor::new(vec![4, 32, 32, 3], rng.normals(4 * 32 * 32 * 3));

    println!("| K | mode | median ms | mults | shifts | adds |");
    println!("|---|---|---|---|---|---|");
    for k in [4usize, 16] {
        for (mode, pow2) in [(ExecMode::Dense, false),
                             (ExecMode::LutTrick, false),
                             (ExecMode::ShiftOnly, true)] {
            let (graph, model) = synth_model(k, pow2);
            let opts = EngineOptions {
                mode,
                act_bits: 8,
                mlbn: mode == ExecMode::ShiftOnly,
            };
            let engine = Engine::new(&graph, &model, opts);
            let (_, counts) = engine.run(&x).expect("run");
            let r = bench(2, 8, || {
                let _ = engine.run(&x).unwrap();
            });
            println!(
                "| {k} | {mode:?} | {:.2} | {} | {} | {} |",
                r.median_ms(),
                counts.mults,
                counts.shifts,
                counts.adds
            );
            if mode == ExecMode::ShiftOnly {
                assert!(counts.is_multiplierless());
            }
        }
    }
    println!("\nexpected: LUT-trick mults = K per accumulator (vs fan-in \
              dense); shift-only executes 0 multiplies");
}
