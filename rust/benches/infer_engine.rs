//! Perf bench: plan/execute inference engine.
//!
//! Two questions, answered with p50/p99 latency and images/sec:
//!   1. What does compile-once buy over the legacy compile-per-call path
//!      (graph re-lowered, assignments re-unpacked every request)?
//!   2. What does batch parallelism add on top?
//!
//! Also regenerates the dense vs LUT-trick vs shift-only op-count table
//! that motivates the kernels. Writes reports/BENCH_infer_plan.json so
//! the perf trajectory is tracked across PRs. Feeds EXPERIMENTS.md §Perf.

mod common;

use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::params::export::{LutLayer, QuantizedModel};
use lutq::params::HostTensor;
use lutq::quant::bitpack::pack_assignments;
use lutq::report::{latency_reports_json, write_report, LatencyReport};
use lutq::util::{Rng, Timer};

/// Build a synthetic 3-conv model directly (no training needed for perf).
fn synth_model(k: usize, pow2: bool) -> (lutq::jsonic::Json, QuantizedModel) {
    let graph = lutq::jsonic::parse(
        r#"[
        {"op":"conv","name":"c0","cin":3,"cout":16,"k":3,"stride":1},
        {"op":"bn","name":"b0","c":16},
        {"op":"relu"},
        {"op":"conv","name":"c1","cin":16,"cout":32,"k":3,"stride":2},
        {"op":"bn","name":"b1","c":32},
        {"op":"relu"},
        {"op":"gap"},
        {"op":"affine","name":"head","cin":32,"cout":10}
    ]"#,
    )
    .unwrap();
    let mut rng = Rng::new(7);
    let mut model = QuantizedModel::default();
    let dict: Vec<f32> = if pow2 {
        (0..k)
            .map(|i| {
                let e = (i as i32 % 8) - 4;
                let s = if i % 2 == 0 { 1.0 } else { -1.0 };
                s * (e as f32).exp2()
            })
            .collect()
    } else {
        (0..k).map(|_| rng.normal() * 0.2).collect()
    };
    for (name, shape) in [("c0", vec![3, 3, 3, 16]),
                          ("c1", vec![3, 3, 16, 32]),
                          ("head", vec![32, 10])] {
        let n: usize = shape.iter().product();
        let assign: Vec<u32> = (0..n).map(|_| rng.below(k) as u32).collect();
        model.lut_layers.push(LutLayer::new(
            name,
            dict.clone(),
            pack_assignments(&assign, k),
            shape,
        ));
    }
    for (name, c) in [("b0", 16), ("b1", 32)] {
        model.fp.insert(format!("{name}.gamma"),
                        HostTensor::f32(vec![c], vec![1.0; c]));
        model.fp.insert(format!("{name}.beta"),
                        HostTensor::f32(vec![c], vec![0.0; c]));
        model.fp.insert(format!("{name}.rmean"),
                        HostTensor::f32(vec![c], vec![0.0; c]));
        model.fp.insert(format!("{name}.rvar"),
                        HostTensor::f32(vec![c], vec![1.0; c]));
    }
    model.fp.insert("head.b".into(),
                    HostTensor::f32(vec![10], vec![0.0; 10]));
    (graph, model)
}

fn popts(mode: ExecMode, threads: usize) -> PlanOptions {
    PlanOptions { mode, act_bits: 8, mlbn: mode == ExecMode::ShiftOnly,
                  threads }
}

/// Per-request latencies (ms) + total wall seconds for `iters` calls.
fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F)
                       -> (Vec<f32>, f64) {
    for _ in 0..warmup {
        f();
    }
    let wall = Timer::start();
    let mut lat = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        lat.push(t.elapsed_ms() as f32);
    }
    (lat, wall.elapsed_s())
}

fn main() {
    common::hr("infer_engine — plan/execute vs legacy compile-per-call");
    let batch = 8usize;
    let iters = common::steps_or(12);
    let mut rng = Rng::new(1);
    let x = Tensor::new(vec![batch, 32, 32, 3],
                        rng.normals(batch * 32 * 32 * 3));
    let (graph, model) = synth_model(4, false);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // sanity: compile-once output is bit-identical to compile-per-call
    let p1 = Plan::compile(&graph, &model, popts(ExecMode::LutTrick, 1),
                           &[32, 32, 3])
        .expect("compile");
    let mut s1 = p1.scratch();
    let (y_once, c_once) = p1.run(&x, &mut s1).expect("run");
    {
        let p = Plan::compile(&graph, &model,
                              popts(ExecMode::LutTrick, 1), &[32, 32, 3])
            .expect("compile");
        let mut s = p.scratch();
        let (y_fresh, c_fresh) = p.run(&x, &mut s).expect("run");
        assert_eq!(y_once.data, y_fresh.data);
        assert_eq!(c_once, c_fresh);
    }

    let mut rows: Vec<LatencyReport> = Vec::new();

    // legacy path: re-lower graph + re-resolve weights on every request
    let (lat, total) = measure(1, iters, || {
        let p = Plan::compile(&graph, &model,
                              popts(ExecMode::LutTrick, 1), &[32, 32, 3])
            .expect("compile");
        let mut s = p.scratch();
        p.run_into(&x, &mut s).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        "lut4/compile-per-call/1t", batch, 1, true, &lat, total));

    // compiled plan, single thread
    let (lat, total) = measure(2, iters, || {
        p1.run_into(&x, &mut s1).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        "lut4/compile-once/1t", batch, 1, false, &lat, total));

    // compiled plan, batch-parallel
    let pn = Plan::compile(&graph, &model, popts(ExecMode::LutTrick, 0),
                           &[32, 32, 3])
        .expect("compile");
    let mut sn = pn.scratch();
    let (lat, total) = measure(2, iters, || {
        pn.run_into(&x, &mut sn).expect("run");
    });
    rows.push(LatencyReport::from_latencies(
        format!("lut4/compile-once/{cores}t"), batch, cores, false, &lat,
        total));

    println!("| path | p50 ms | p99 ms | images/s |");
    println!("|---|---|---|---|");
    for r in &rows {
        println!("| {} | {:.2} | {:.2} | {:.1} |", r.label, r.p50_ms,
                 r.p99_ms, r.images_per_sec);
    }
    let speedup = rows[0].p50_ms / rows[1].p50_ms.max(1e-6);
    println!("\ncompile-once single-thread speedup vs compile-per-call: \
              {speedup:.2}x (target >= 3x at batch {batch})");

    // ------------------------------------------------- op-count table
    common::hr("op counts — dense vs LUT-trick vs shift-only");
    println!("| K | mode | median ms | mults | shifts | adds |");
    println!("|---|---|---|---|---|---|");
    let xt = Tensor::new(vec![4, 32, 32, 3],
                         Rng::new(3).normals(4 * 32 * 32 * 3));
    for k in [4usize, 16] {
        for (mode, pow2) in [(ExecMode::Dense, false),
                             (ExecMode::LutTrick, false),
                             (ExecMode::ShiftOnly, true)] {
            let (graph, model) = synth_model(k, pow2);
            let plan = Plan::compile(&graph, &model, popts(mode, 1),
                                     &[32, 32, 3])
                .expect("compile");
            let mut s = plan.scratch();
            let counts = plan.run_into(&xt, &mut s).expect("run");
            let (lat, _) = measure(1, 5, || {
                plan.run_into(&xt, &mut s).expect("run");
            });
            println!(
                "| {k} | {mode:?} | {:.2} | {} | {} | {} |",
                lutq::util::stats::quantile(&lat, 0.5),
                counts.mults,
                counts.shifts,
                counts.adds
            );
            if mode == ExecMode::ShiftOnly {
                assert!(counts.is_multiplierless());
            }
        }
    }
    println!("\nexpected: LUT-trick mults = K per accumulator (vs fan-in \
              dense); shift-only executes 0 multiplies");

    let path = write_report(&lutq::reports_dir(), "BENCH_infer_plan.json",
                            &latency_reports_json(&rows))
        .expect("write report");
    println!("\nwrote {}", path.display());
}
